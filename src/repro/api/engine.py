"""Engine: the single front door to the Fograph serving pipeline.

    Engine(model, cluster, **knobs).compile(graph) -> Plan
    Plan.session() -> Session -> Session.query() -> QueryResult
    Plan.server() -> Server -> Server.replay(trace) -> [Response, ...]

``Engine`` captures the pipeline *configuration* (every stage is a
string-keyed registry entry); ``compile`` runs the paper's setup phase once
— fog profiling/metadata registration, IEP data placement, static-shape
partition buffers — and freezes the result into an immutable ``Plan``.
Swapping the executor backend between "sim", "single", "mesh-bsp" and
"cloud" (or the compressor/exchange/placement between their registry
keys) changes no other code.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.api import executors as _executors  # noqa: F401  (registers backends)
from repro.api.registry import (COMPRESSORS, EXCHANGES, EXECUTORS,
                                PARTITIONERS, PLACEMENTS)
from repro.api.plan import EngineConfig, ModelSpec, Plan, as_model
from repro.api.updates import GraphDelta, UpdateReport
from repro.core import incremental, simulation
from repro.gnn.graph import Graph
from repro.kernels import ops
from repro.runtime import bsp


class Engine:
    """A configured-but-uncompiled serving pipeline.

    Args:
      model: ``ModelSpec`` or ``(params, kind)`` pair.
      cluster: a cluster-spec string like ``"1A+4B+1C"`` (paper Table II
        node types; built at compile time against the query graph) or a
        prebuilt ``simulation.FogCluster``.
      partitioner / placement / compressor / exchange / executor: registry
        keys for the five pluggable stages. Unknown keys raise immediately
        with the list of available options.
      aggregation: shard-local aggregation path — "segment_sum" (gather +
        ``jax.ops.segment_sum``), "pallas" (the block-CSR Pallas kernels;
        strict — raises for unsupported kind/exchange combinations) or
        "auto" (kernels wherever supported when running on TPU, else
        segment_sum). With a DAQ compressor the mesh executor's kernel
        path also quantizes the halo wire and dequantizes inside the
        fused ``dequant_spmm`` kernel.
      staleness_bound: with the stale-tolerant ``"halo_async"`` exchange,
        how many serves may replay recorded halo tables before the next
        fresh synchronous exchange is forced (0 = every serve syncs,
        bit-identical to ``exchange="halo"``). Rejected for exchanges
        without stale tolerance.
      network: collection-network profile ("wifi" / "4g" / "5g").
      hidden: hidden width used by the analytic workload model.
      sync_cost: one BSP synchronization (delta in Eq. 6/7).
      bytes_per_vertex: per-vertex upload size for planning (defaults to
        the graph's raw float64 feature bytes).
      seed: profiling/placement RNG seed.
      update_max_imbalance / update_max_cut_growth: repair-quality
        thresholds for ``apply_delta`` — when the incrementally repaired
        partitioning exceeds either, the delta triggers a full recompile
        instead (overridable per call).
      validate: static plan verification mode — "off" (default), "warn"
        (emit ``PlanInvariantWarning`` per finding) or "strict" (raise
        ``repro.analysis.PlanValidationError``). Runs the
        ``repro.analysis`` plan invariant checks at ``compile`` /
        ``apply_delta`` exit; see ``docs/analysis.md``.
    """

    def __init__(self, model, cluster: Union[str, "simulation.FogCluster"]
                 = "1A+4B+1C", *, network: str = "wifi",
                 partitioner: str = "bgp", placement: str = "iep",
                 compressor: str = "daq", exchange: str = "halo",
                 executor: str = "sim", hidden: int = 64, seed: int = 0,
                 sync_cost: float = simulation.DEFAULT_SYNC_COST,
                 bytes_per_vertex: Optional[float] = None,
                 aggregation: str = "auto",
                 staleness_bound: int = 0,
                 update_max_imbalance: float = 2.0,
                 update_max_cut_growth: float = 1.5,
                 validate: str = "off"):
        self.model: ModelSpec = as_model(model)
        self.cluster = cluster
        # Resolve every stage eagerly so bad keys fail at construction.
        self._partitioner = PARTITIONERS.resolve(partitioner)
        self._placement = PLACEMENTS.resolve(placement)
        self._compressor = COMPRESSORS.resolve(
            "none" if compressor is None else compressor)
        self._exchange = EXCHANGES.resolve(exchange)
        self._executor = EXECUTORS.resolve(executor)
        # Validate the aggregation knob eagerly too: "pallas" is strict
        # about the model kind (and about the exchange on backends that
        # aggregate over the per-shard block-CSR operands).
        bsp.resolve_aggregation(
            aggregation, self.model.kind,
            exchange=exchange if getattr(self._executor,
                                         "needs_block_shards", False)
            else None)
        if validate not in ("off", "warn", "strict"):
            raise ValueError(f"unknown validate mode {validate!r}; "
                             f"available: off, warn, strict")
        staleness_bound = int(staleness_bound)
        if staleness_bound < 0:
            raise ValueError(f"staleness_bound must be >= 0, "
                             f"got {staleness_bound}")
        if staleness_bound > 0 and not getattr(self._exchange,
                                               "stale_tolerant", False):
            raise ValueError(
                f"staleness_bound={staleness_bound} needs a stale-tolerant "
                f"exchange (e.g. 'halo_async'), got "
                f"{EXCHANGES.canonical(exchange)!r}")
        self.config = EngineConfig(
            partitioner=PARTITIONERS.canonical(partitioner),
            placement=PLACEMENTS.canonical(placement),
            compressor=COMPRESSORS.canonical(
                "none" if compressor is None else compressor),
            exchange=EXCHANGES.canonical(exchange),
            executor=EXECUTORS.canonical(executor),
            network=network,
            cluster_spec=cluster if isinstance(cluster, str) else None,
            hidden=hidden, seed=seed, sync_cost=sync_cost,
            bytes_per_vertex=bytes_per_vertex, aggregation=aggregation,
            staleness_bound=staleness_bound,
            update_max_imbalance=update_max_imbalance,
            update_max_cut_growth=update_max_cut_growth,
            validate=validate)

    def _validated(self, plan: Plan) -> Plan:
        """Run the static plan invariant checks per ``config.validate``."""
        if self.config.validate != "off":
            from repro.analysis import verify_plan
            verify_plan(plan, mode=self.config.validate)
        return plan

    def compile(self, graph: Graph) -> Plan:
        """Setup phase (paper steps 1-2): profile, register, plan, freeze."""
        cfg = self.config
        if isinstance(self.cluster, str):
            cluster = simulation.make_cluster(
                self.cluster, cfg.network, graph, hidden=cfg.hidden,
                k_layers=self.model.num_layers, seed=cfg.seed,
                sync_cost=cfg.sync_cost)
        else:
            cluster = self.cluster
        # step 1: metadata registration — profile every fog node.
        fogs = tuple(cluster.fog_specs(seed=cfg.seed))
        # step 2: execution planning — partition + partition->fog mapping.
        placement = self._placement.place(
            graph, fogs, k_layers=self.model.num_layers,
            sync_cost=cluster.sync_cost, seed=cfg.seed,
            bytes_per_vertex=cfg.bytes_per_vertex,
            partitioner=self._partitioner)
        # Freeze the static-shape per-partition buffers once. The block-CSR
        # shards are only built when this engine's own backend would read
        # them (sessions that override to a kernel path rebuild lazily).
        needs_shards = getattr(self._executor, "needs_block_shards", False)
        mode = bsp.resolve_aggregation(
            cfg.aggregation, self.model.kind,
            exchange=cfg.exchange if needs_shards else None)
        partitioned = bsp.build_partitioned(
            graph, placement.assignment,
            build_blocks=needs_shards and mode == "pallas")
        return self._validated(
            Plan(model=self.model, graph=graph, cluster=cluster,
                 fogs=fogs, placement=placement, partitioned=partitioned,
                 config=cfg))

    def compile_fleet(self, graph: Graph, sites) -> "Fleet":
        """Compile a geo-distributed fleet: one Plan per named fog site
        plus the ``"cloud"`` executor as last-resort tier.

        ``sites`` maps site name -> ``(lat, lon)`` centroid (dict, or a
        sequence of ``(name, (lat, lon))`` / ``(name, lat, lon)``
        entries). Every site serves THIS engine's model with THIS
        engine's pipeline knobs; each runs its own setup phase with a
        per-site profiling seed (``seed + index``) — N independently
        profiled deployments of one shared fog model, the paper's
        multi-edge-server shape. The cloud plan is the same model
        compiled for ``executor="cloud"`` (always fresh: no cross-fog
        exchange, so ``staleness_bound`` does not apply there).

        Returns a :class:`repro.api.fleet.Fleet`; open the serving
        facade with ``fleet.server(...)``.
        """
        from repro.api.fleet import Fleet, Site
        if isinstance(sites, dict):
            items = list(sites.items())
        else:
            items = []
            for entry in sites:
                entry = tuple(entry)
                if len(entry) == 3:          # (name, lat, lon)
                    items.append((entry[0], (entry[1], entry[2])))
                elif len(entry) == 2:        # (name, (lat, lon))
                    items.append((entry[0], tuple(entry[1])))
                else:
                    raise ValueError(
                        f"site entry must be (name, (lat, lon)) or "
                        f"(name, lat, lon), got {entry!r}")
        if not items:
            raise ValueError("compile_fleet needs at least one site")
        cfg = self.config
        cluster = cfg.cluster_spec if cfg.cluster_spec else self.cluster

        def _engine(**over) -> "Engine":
            kw = dict(network=cfg.network, partitioner=cfg.partitioner,
                      placement=cfg.placement, compressor=cfg.compressor,
                      exchange=cfg.exchange, executor=cfg.executor,
                      hidden=cfg.hidden, seed=cfg.seed,
                      sync_cost=cfg.sync_cost,
                      bytes_per_vertex=cfg.bytes_per_vertex,
                      aggregation=cfg.aggregation,
                      staleness_bound=cfg.staleness_bound,
                      update_max_imbalance=cfg.update_max_imbalance,
                      update_max_cut_growth=cfg.update_max_cut_growth,
                      validate=cfg.validate)
            kw.update(over)
            return Engine(self.model, cluster, **kw)

        site_objs = tuple(
            Site(name=name, location=loc,
                 plan=_engine(seed=cfg.seed + i).compile(graph))
            for i, (name, loc) in enumerate(items))
        cloud_plan = _engine(executor="cloud", staleness_bound=0
                             ).compile(graph)
        return Fleet(sites=site_objs, cloud_plan=cloud_plan)

    @classmethod
    def from_plan(cls, plan: Plan) -> "Engine":
        """Reconstruct the Engine a plan was compiled with (same knobs).

        Used by ``Session.update`` to repair or recompile without the
        caller having kept the original Engine around.  Plans compiled
        from a cluster-spec string rebuild the cluster against whatever
        graph they next compile; plans compiled from a prebuilt
        ``FogCluster`` reuse that instance.
        """
        cfg = plan.config
        return cls(plan.model,
                   cfg.cluster_spec if cfg.cluster_spec else plan.cluster,
                   network=cfg.network, partitioner=cfg.partitioner,
                   placement=cfg.placement, compressor=cfg.compressor,
                   exchange=cfg.exchange, executor=cfg.executor,
                   hidden=cfg.hidden, seed=cfg.seed,
                   sync_cost=cfg.sync_cost,
                   bytes_per_vertex=cfg.bytes_per_vertex,
                   aggregation=cfg.aggregation,
                   staleness_bound=cfg.staleness_bound,
                   update_max_imbalance=cfg.update_max_imbalance,
                   update_max_cut_growth=cfg.update_max_cut_growth,
                   validate=cfg.validate)

    # -- node-level fault tolerance ------------------------------------------

    def fail_nodes(self, plan: Plan, crashed, *,
                   assignment: Optional[np.ndarray] = None,
                   mode: Optional[str] = None) -> Plan:
        """Shard failover: evict crashed nodes, re-place their shards.

        ``crashed`` is one node name / index or a sequence of them
        (``SimNode.name`` entries of ``plan.cluster.nodes``). The default
        repair path keeps the survivors' profiled fog metadata and runs
        PR 4's machinery — ``evacuate_assignment`` marks the crashed
        shards' vertices unassigned, ``repair_assignment`` greedily
        re-places them onto the survivors (min-cut-aware,
        capacity-bounded), ``refresh_placement`` re-prices — falling back
        to a full compile on the surviving cluster when the repaired
        partitioning degrades past ``config.update_max_imbalance``.
        ``mode`` forces "repair" or "recompile" ("recompile" is
        *bit-identical to a fresh* ``Engine.compile`` *on the surviving
        cluster* by construction — it runs exactly that setup phase).

        The returned Plan has ``provenance="failover"``, a
        degraded-capacity ``cluster`` holding only the survivors, and —
        deliberately — ``config.cluster_spec=None``: a failover plan
        carrying the original spec string would resurrect the crashed
        node on the next ``from_plan`` recompile and price update
        repairs against capacity that no longer exists.
        """
        if mode not in (None, "repair", "recompile"):
            raise ValueError(f"mode must be None, 'repair' or 'recompile', "
                             f"got {mode!r}")
        nodes = plan.cluster.nodes
        names = [n.name for n in nodes]
        if isinstance(crashed, (str, int, np.integer)):
            crashed = [crashed]
        evicted = set()
        for c in crashed:
            if isinstance(c, (int, np.integer)):
                j = int(c)
                if not 0 <= j < len(nodes):
                    raise ValueError(f"node index {j} out of range for "
                                     f"{len(nodes)} nodes")
            else:
                if c not in names:
                    raise KeyError(f"unknown node {c!r}; cluster has: "
                                   f"{', '.join(names)}")
                j = names.index(c)
            evicted.add(j)
        if not evicted:
            raise ValueError("fail_nodes needs at least one crashed node")
        keep = [j for j in range(len(nodes)) if j not in evicted]
        if not keep:
            raise ValueError(
                f"cannot fail every node ({sorted(names[j] for j in evicted)}"
                f" is the whole cluster); at least one must survive")
        cfg = plan.config
        survivors = dataclasses.replace(
            plan.cluster, nodes=[nodes[j] for j in keep])
        if mode != "recompile":
            base = (plan.placement.assignment if assignment is None
                    else np.asarray(assignment, np.int64))
            evacuated = incremental.evacuate_assignment(base, keep,
                                                        len(nodes))
            repaired = incremental.repair_assignment(plan.graph, evacuated,
                                                     len(keep))
            imb_before = incremental.imbalance_of(base, len(nodes))
            imb = incremental.imbalance_of(repaired, len(keep))
            if (mode == "repair"
                    or imb <= cfg.update_max_imbalance
                    * max(1.0, imb_before)):
                fogs = tuple(plan.fogs[j] for j in keep)
                placement = incremental.refresh_placement(
                    plan.graph, repaired, np.arange(len(keep)), fogs,
                    bytes_per_vertex=cfg.bytes_per_vertex,
                    k_layers=self.model.num_layers,
                    sync_cost=plan.cluster.sync_cost)
                needs_shards = getattr(self._executor, "needs_block_shards",
                                       False)
                agg = bsp.resolve_aggregation(
                    cfg.aggregation, self.model.kind,
                    exchange=cfg.exchange if needs_shards else None)
                build_blocks = ((needs_shards and agg == "pallas")
                                or plan.partitioned.local_csr is not None)
                partitioned = bsp.build_partitioned(
                    plan.graph, repaired, build_blocks=build_blocks,
                    n=len(keep))
                return self._validated(Plan(
                    model=self.model, graph=plan.graph, cluster=survivors,
                    fogs=fogs, placement=placement, partitioned=partitioned,
                    config=cfg.with_overrides(cluster_spec=None),
                    provenance="failover"))
        # Recompile: the full setup phase on the surviving cluster (fresh
        # per-node profiling seeds at the survivors' new indices) — the
        # result IS a fresh Engine.compile of that cluster, re-tagged.
        eng = Engine(self.model, survivors, network=cfg.network,
                     partitioner=cfg.partitioner, placement=cfg.placement,
                     compressor=cfg.compressor, exchange=cfg.exchange,
                     executor=cfg.executor, hidden=cfg.hidden,
                     seed=cfg.seed, sync_cost=cfg.sync_cost,
                     bytes_per_vertex=cfg.bytes_per_vertex,
                     aggregation=cfg.aggregation,
                     staleness_bound=cfg.staleness_bound,
                     update_max_imbalance=cfg.update_max_imbalance,
                     update_max_cut_growth=cfg.update_max_cut_growth,
                     validate=cfg.validate)
        return dataclasses.replace(eng.compile(plan.graph),
                                   provenance="failover")

    # -- dynamic-graph updates ----------------------------------------------

    def _recompile(self, graph: Graph) -> Plan:
        """Full setup phase against a mutated graph (the fallback path)."""
        if isinstance(self.cluster, str):
            return self.compile(graph)
        # A prebuilt FogCluster was profiled against the old graph; rebind
        # it to the mutated one so wire bytes / ground truth stay honest.
        old = self.cluster
        self.cluster = dataclasses.replace(old, graph=graph,
                                           feature_dim=graph.feature_dim)
        try:
            return self.compile(graph)
        finally:
            self.cluster = old

    def apply_delta(self, plan: Plan,
                    delta: Union[GraphDelta, Sequence[GraphDelta]], *,
                    assignment: Optional[np.ndarray] = None,
                    max_imbalance: Optional[float] = None,
                    max_cut_growth: Optional[float] = None,
                    force: Optional[str] = None) -> Plan:
        """Absorb a graph mutation into ``plan`` without recomputing the
        world (paper §III-E workload adaptation, ROADMAP "Dynamic graphs").

        The repair path keeps the plan's profiled fog metadata and
        partition -> fog mapping, greedily assigns new vertices into the
        existing partitions (min-cut-aware, capacity-bounded), rebuilds
        only the *dirty* shards' block-CSR operands and halo exchange
        maps, and re-prices the placement estimates for the mutated
        topology.  When the repaired partitioning degrades past the
        thresholds — imbalance above ``max_imbalance`` x the pre-update
        imbalance (floored at a balanced baseline) or edge-cut fraction
        above ``max_cut_growth`` x the pre-update cut — the full compile
        pipeline runs instead.

        Args:
          plan: the plan to update (left untouched; a new Plan returns).
          delta: one ``GraphDelta`` or a sequence applied in order (each
            delta addresses the graph produced by the previous one).
          assignment: base vertex -> fog assignment to repair (defaults to
            ``plan.placement.assignment``; sessions pass their adapted
            assignment).
          force: "incremental" skips the threshold check, "recompile"
            skips the repair.

        Returns a Plan with ``provenance`` of "incremental" or "recompile"
        and an ``update_report`` describing what happened; empty deltas
        return an equivalent plan with mode "noop".
        """
        cfg = plan.config
        deltas = [delta] if isinstance(delta, GraphDelta) else list(delta)
        if force not in (None, "incremental", "recompile"):
            raise ValueError(f"force must be None, 'incremental' or "
                             f"'recompile', got {force!r}")
        max_imbalance = (cfg.update_max_imbalance if max_imbalance is None
                         else max_imbalance)
        max_cut_growth = (cfg.update_max_cut_growth if max_cut_growth is None
                          else max_cut_growth)
        base = (plan.placement.assignment if assignment is None
                else np.asarray(assignment, np.int64))
        n = plan.num_fogs
        dp = incremental.plan_delta(plan.graph, base, deltas, n)
        report_kw = dict(num_deltas=len(deltas), num_partitions=n,
                         imbalance_before=dp.imbalance_before,
                         imbalance=dp.imbalance,
                         cut_fraction_before=dp.cut_fraction_before,
                         cut_fraction_after=dp.cut_fraction_after,
                         **dp.counts)

        if (not dp.structural and dp.counts["feature_upserts"] == 0
                and np.array_equal(base, plan.placement.assignment)
                and force != "recompile"):
            report = UpdateReport(mode="noop", **report_kw)
            return self._validated(
                dataclasses.replace(plan, provenance="incremental",
                                    update_report=report))

        recompile_reason = ""
        if force != "incremental" and dp.structural:
            # Both thresholds bound *degradation* relative to the plan
            # being repaired (floored at a perfectly balanced baseline):
            # IEP sizes partitions to heterogeneous capability, so a
            # skewed-but-intended layout must not trip the knob by itself.
            imbalance_limit = max_imbalance * max(1.0, dp.imbalance_before)
            if dp.imbalance > imbalance_limit:
                recompile_reason = (f"imbalance {dp.imbalance:.2f} > "
                                    f"{max_imbalance:.2f} x "
                                    f"{max(1.0, dp.imbalance_before):.2f}")
            elif dp.cut_fraction_after > max_cut_growth * max(
                    dp.cut_fraction_before, 1e-9):
                recompile_reason = (
                    f"cut fraction {dp.cut_fraction_after:.3f} > "
                    f"{max_cut_growth:.2f} x {dp.cut_fraction_before:.3f}")
        if force == "recompile":
            recompile_reason = "forced"
        if dp.structural:
            # The adjacency changed: retire the pre-update graph's cached
            # whole-graph block-CSR operands (the single-program kernel
            # path's keyed cache) alongside the dirty-shard rebuild. The
            # mutated graph fingerprints differently, so stale operands
            # can never be served — this just stops them pinning memory
            # until LRU eviction (a session still on the old plan simply
            # re-blocks on demand).
            ops.invalidate_block_csr(plan.graph)
        if recompile_reason:
            plan2 = self._recompile(dp.graph)
            report = UpdateReport(mode="recompile", reason=recompile_reason,
                                  **report_kw)
            return dataclasses.replace(plan2, provenance="recompile",
                                       update_report=report)

        # plan.partitioned was laid out for plan.placement.assignment; it
        # is only a valid reuse source (for clean-shard tiles, or for the
        # feature-only with_features fast path) when the repair started
        # from that same assignment. A session that adapted migrates
        # vertices without touching plan.partitioned, so its repairs must
        # rebuild from scratch for the adapted assignment.
        base_is_plan = np.array_equal(base, plan.placement.assignment)
        needs_shards = getattr(self._executor, "needs_block_shards", False)
        mode = bsp.resolve_aggregation(
            cfg.aggregation, self.model.kind,
            exchange=cfg.exchange if needs_shards else None)
        build_blocks = (needs_shards and mode == "pallas"
                        ) or plan.partitioned.local_csr is not None
        if not dp.structural and base_is_plan:
            # Feature-only: same topology, same layout, same block shards —
            # only the per-partition feature table is refreshed.
            partitioned = plan.partitioned.with_features(dp.graph.features)
            dirty_l = dirty_h = ()
        elif not dp.structural:
            # Feature-only delta on an adapted assignment: the delta
            # dirtied nothing, but the layout must match the adapted
            # assignment, which plan.partitioned does not.
            partitioned = bsp.build_partitioned(
                dp.graph, dp.assignment, build_blocks=build_blocks, n=n)
            dirty_l = dirty_h = ()
        else:
            partitioned = bsp.build_partitioned(
                dp.graph, dp.assignment, build_blocks=build_blocks, n=n,
                prev=plan.partitioned if base_is_plan else None,
                dirty_local=dp.dirty_local, dirty_halo=dp.dirty_halo)
            dirty_l = tuple(int(p) for p in dp.dirty_local)
            dirty_h = tuple(int(p) for p in dp.dirty_halo)
        placement = incremental.refresh_placement(
            dp.graph, dp.assignment, plan.placement.mapping, plan.fogs,
            bytes_per_vertex=cfg.bytes_per_vertex,
            k_layers=self.model.num_layers,
            sync_cost=plan.cluster.sync_cost)
        cluster = dataclasses.replace(plan.cluster, graph=dp.graph,
                                      feature_dim=dp.graph.feature_dim)
        report = UpdateReport(
            mode="features" if not dp.structural else "incremental",
            dirty_local=dirty_l, dirty_halo=dirty_h, **report_kw)
        return self._validated(
            Plan(model=self.model, graph=dp.graph, cluster=cluster,
                 fogs=plan.fogs, placement=placement,
                 partitioned=partitioned, config=cfg,
                 provenance="incremental", update_report=report))

    def __repr__(self) -> str:
        c = self.config
        return (f"Engine(kind={self.model.kind!r}, "
                f"cluster={c.cluster_spec or 'custom'}, "
                f"placement={c.placement!r}, compressor={c.compressor!r}, "
                f"exchange={c.exchange!r}, executor={c.executor!r}, "
                f"aggregation={c.aggregation!r})")
