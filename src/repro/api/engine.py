"""Engine: the single front door to the Fograph serving pipeline.

    Engine(model, cluster, **knobs).compile(graph) -> Plan
    Plan.session() -> Session -> Session.query() -> QueryResult
    Plan.server() -> Server -> Server.replay(trace) -> [Response, ...]

``Engine`` captures the pipeline *configuration* (every stage is a
string-keyed registry entry); ``compile`` runs the paper's setup phase once
— fog profiling/metadata registration, IEP data placement, static-shape
partition buffers — and freezes the result into an immutable ``Plan``.
Swapping the executor backend between "sim", "single", "mesh-bsp" and
"cloud" (or the compressor/exchange/placement between their registry
keys) changes no other code.
"""
from __future__ import annotations

from typing import Optional, Union

from repro.api import executors as _executors  # noqa: F401  (registers backends)
from repro.api.registry import (COMPRESSORS, EXCHANGES, EXECUTORS,
                                PARTITIONERS, PLACEMENTS)
from repro.api.plan import EngineConfig, ModelSpec, Plan, as_model
from repro.core import simulation
from repro.gnn.graph import Graph
from repro.runtime import bsp


class Engine:
    """A configured-but-uncompiled serving pipeline.

    Args:
      model: ``ModelSpec`` or ``(params, kind)`` pair.
      cluster: a cluster-spec string like ``"1A+4B+1C"`` (paper Table II
        node types; built at compile time against the query graph) or a
        prebuilt ``simulation.FogCluster``.
      partitioner / placement / compressor / exchange / executor: registry
        keys for the five pluggable stages. Unknown keys raise immediately
        with the list of available options.
      aggregation: shard-local aggregation path — "segment_sum" (gather +
        ``jax.ops.segment_sum``), "pallas" (the block-CSR Pallas kernels;
        strict — raises for unsupported kind/exchange combinations) or
        "auto" (kernels wherever supported when running on TPU, else
        segment_sum). With a DAQ compressor the mesh executor's kernel
        path also quantizes the halo wire and dequantizes inside the
        fused ``dequant_spmm`` kernel.
      network: collection-network profile ("wifi" / "4g" / "5g").
      hidden: hidden width used by the analytic workload model.
      sync_cost: one BSP synchronization (delta in Eq. 6/7).
      bytes_per_vertex: per-vertex upload size for planning (defaults to
        the graph's raw float64 feature bytes).
      seed: profiling/placement RNG seed.
    """

    def __init__(self, model, cluster: Union[str, "simulation.FogCluster"]
                 = "1A+4B+1C", *, network: str = "wifi",
                 partitioner: str = "bgp", placement: str = "iep",
                 compressor: str = "daq", exchange: str = "halo",
                 executor: str = "sim", hidden: int = 64, seed: int = 0,
                 sync_cost: float = simulation.DEFAULT_SYNC_COST,
                 bytes_per_vertex: Optional[float] = None,
                 aggregation: str = "auto"):
        self.model: ModelSpec = as_model(model)
        self.cluster = cluster
        # Resolve every stage eagerly so bad keys fail at construction.
        self._partitioner = PARTITIONERS.resolve(partitioner)
        self._placement = PLACEMENTS.resolve(placement)
        self._compressor = COMPRESSORS.resolve(
            "none" if compressor is None else compressor)
        self._exchange = EXCHANGES.resolve(exchange)
        self._executor = EXECUTORS.resolve(executor)
        # Validate the aggregation knob eagerly too: "pallas" is strict
        # about the model kind (and about the exchange on backends that
        # aggregate over the per-shard block-CSR operands).
        bsp.resolve_aggregation(
            aggregation, self.model.kind,
            exchange=exchange if getattr(self._executor,
                                         "needs_block_shards", False)
            else None)
        self.config = EngineConfig(
            partitioner=PARTITIONERS.canonical(partitioner),
            placement=PLACEMENTS.canonical(placement),
            compressor=COMPRESSORS.canonical(
                "none" if compressor is None else compressor),
            exchange=EXCHANGES.canonical(exchange),
            executor=EXECUTORS.canonical(executor),
            network=network,
            cluster_spec=cluster if isinstance(cluster, str) else None,
            hidden=hidden, seed=seed, sync_cost=sync_cost,
            bytes_per_vertex=bytes_per_vertex, aggregation=aggregation)

    def compile(self, graph: Graph) -> Plan:
        """Setup phase (paper steps 1-2): profile, register, plan, freeze."""
        cfg = self.config
        if isinstance(self.cluster, str):
            cluster = simulation.make_cluster(
                self.cluster, cfg.network, graph, hidden=cfg.hidden,
                k_layers=self.model.num_layers, seed=cfg.seed,
                sync_cost=cfg.sync_cost)
        else:
            cluster = self.cluster
        # step 1: metadata registration — profile every fog node.
        fogs = tuple(cluster.fog_specs(seed=cfg.seed))
        # step 2: execution planning — partition + partition->fog mapping.
        placement = self._placement.place(
            graph, fogs, k_layers=self.model.num_layers,
            sync_cost=cluster.sync_cost, seed=cfg.seed,
            bytes_per_vertex=cfg.bytes_per_vertex,
            partitioner=self._partitioner)
        # Freeze the static-shape per-partition buffers once. The block-CSR
        # shards are only built when this engine's own backend would read
        # them (sessions that override to a kernel path rebuild lazily).
        needs_shards = getattr(self._executor, "needs_block_shards", False)
        mode = bsp.resolve_aggregation(
            cfg.aggregation, self.model.kind,
            exchange=cfg.exchange if needs_shards else None)
        partitioned = bsp.build_partitioned(
            graph, placement.assignment,
            build_blocks=needs_shards and mode == "pallas")
        return Plan(model=self.model, graph=graph, cluster=cluster,
                    fogs=fogs, placement=placement, partitioned=partitioned,
                    config=cfg)

    def __repr__(self) -> str:
        c = self.config
        return (f"Engine(kind={self.model.kind!r}, "
                f"cluster={c.cluster_spec or 'custom'}, "
                f"placement={c.placement!r}, compressor={c.compressor!r}, "
                f"exchange={c.exchange!r}, executor={c.executor!r}, "
                f"aggregation={c.aggregation!r})")
