"""Serving sessions: repeated queries over a compiled Plan.

A ``Session`` owns every piece of mutable runtime state the old
``FographService`` grab-bag mixed into one dataclass:

  * the adaptive scheduler's ``SchedulerState`` (placement drift, mode
    history) — seeded from a *copy* of the plan's placement, so the plan
    itself stays frozen,
  * the partitioned-buffer cache (rebuilt only when adaptation migrates
    vertices),
  * query counters for the ``adapt_every`` tick.

The paper's per-query stages are separately callable — ``collect``
(compressed feature collection, step 3), ``execute`` (distributed
runtime, step 4) and ``account`` (simulated latency pricing) — so the
request-level ``Server`` front-end (``repro.api.server``) can micro-batch
and pipeline them across queries. ``query`` composes the three stages
into the single-shot blocking call.

Every query returns a ``QueryResult`` with one unified metrics schema
across executor backends (sim / single / mesh-bsp / cloud).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Iterable, Iterator, Optional, Union

import numpy as np

from repro.api import executors as _executors  # noqa: F401  (registers backends)
from repro.api.executors import ExecutorBackend
from repro.api.registry import (COMPRESSORS, EXCHANGES, EXECUTORS,
                                PARTITIONERS)
from repro.api.updates import GraphDelta, UpdateReport
from repro.core import frontier as _frontier
from repro.core import simulation
from repro.core.scheduler import SchedulerState, schedule_step
from repro.gnn.graph import Graph
from repro.kernels import ops
from repro.runtime import bsp


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Unified per-query metrics, identical across executor backends.

    ``breakdown`` keys: collect / execute / unpack / total (seconds, for
    the bottleneck fog). ``exchange_bytes`` is the per-BSP-sync collective
    payload under the plan's exchange strategy (0 for the single and cloud
    backends, which have no cross-fog sync). ``accuracy`` is filled by the
    session's ``accuracy_fn`` hook when one is installed.
    """
    embeddings: np.ndarray
    latency: float
    throughput: float
    breakdown: Dict[str, float]
    wire_bytes: float
    exchange_bytes: int
    backend: str
    accuracy: Optional[float] = None


class _HaloStore:
    """Recorded halo tables for stale-tolerant serving (halo_async).

    After a fresh serve the session records every layer's boundary-row
    table (``bsp.build_halo_tables``); up to ``bound`` subsequent serves
    may replay them instead of stalling the BSP superstep on the
    exchange. ``age`` counts serves since the recording pass;
    ``revision`` pins the graph fingerprint the tables were built under
    (any mismatch forces a fresh serve). ``tables`` is None (cold), a
    list of per-layer arrays (mesh backend), or the empty-tuple marker
    for single-program backends — which have no real exchange to skip,
    so only the version/staleness accounting applies.
    """
    __slots__ = ("bound", "tables", "age", "revision")

    def __init__(self, bound: int):
        self.bound = int(bound)
        self.tables = None
        self.age = 0
        self.revision = None

    def invalidate(self) -> None:
        self.tables = None
        self.age = 0
        self.revision = None


class Session:
    """Live serving handle for one Plan: ``query``, ``update``, ``adapt``.

    ``updates`` sets the dynamic-graph consistency policy: "sync" applies
    every ``update(delta)`` immediately (queries after the update always
    see the mutated graph), "deferred" buffers deltas and coalesces them
    into one repair at the next ``flush_updates()`` — queries served in
    between read the stale graph (bounded staleness, amortized repair).

    ``compressor`` and ``num_layers`` are degraded-serving overrides: the
    session serves ``plan.with_overrides(...)`` — same graph, placement
    and partitioned buffers, but a swapped upload codec and/or a
    truncated layer stack. These are the knobs the SLO control plane's
    degradation ladder turns (``repro.api.slo``); a session configured
    with them directly is bit-identical to the server's degraded path.

    ``activation_cache=True`` turns on incremental delta-driven queries:
    the session retains every layer's activations from the last full
    pass, and a query after a (localized) graph update recomputes only
    the k-hop dirty frontier (``core.frontier``), scatter-merging the
    recomputed rows into the cached tables — bit-identical to a full
    recompute, at O(affected) instead of O(V) executor work. Queries
    fall back to a full pass (transparently, repriming the cache) when
    the frontier exceeds ``frontier_max_fraction`` of V, the executor /
    model kind lacks frontier support (GAT re-weights edges per layer),
    or the cached revision/numerics tags disagree.
    """

    def __init__(self, plan, *, executor: Optional[str] = None,
                 aggregation: Optional[str] = None,
                 compressor: Optional[str] = None,
                 num_layers: Optional[int] = None,
                 lam: float = 1.3, theta: float = 0.5,
                 adapt_every: int = 0,
                 accuracy_fn: Optional[Callable[[np.ndarray], float]] = None,
                 seed: Optional[int] = None,
                 updates: str = "sync",
                 activation_cache: bool = False,
                 frontier_max_fraction: float = 0.25,
                 staleness_bound: Optional[int] = None):
        if updates not in ("sync", "deferred"):
            raise ValueError(f"updates must be 'sync' or 'deferred', "
                             f"got {updates!r}")
        # Degraded-serving knobs (the SLO control plane's ladder rungs):
        # the session serves a derived plan sharing this plan's buffers,
        # so the compressor swap / layer truncation is consistent across
        # collection, execution, wire accounting and latency pricing.
        if compressor is not None or num_layers is not None:
            plan = plan.with_overrides(compressor=compressor,
                                       num_layers=num_layers)
        self.plan = plan
        self.update_policy = updates
        self._pending_deltas: list = []
        # (|V|, F) of the graph after every buffered delta: lets update()
        # reject out-of-range deltas at admission instead of poisoning a
        # deferred flush (deferred deltas address the projected graph).
        self._projected_shape = (plan.graph.num_vertices,
                                 plan.graph.feature_dim)
        cfg = plan.config
        self._executor_key = cfg.executor if executor is None else executor
        self._executor = EXECUTORS.resolve(self._executor_key)
        self._compressor = COMPRESSORS.resolve(cfg.compressor)
        self._exchange = EXCHANGES.resolve(cfg.exchange)
        # Shard-local aggregation path override (else the plan's knob);
        # validated eagerly — with the exchange context when the session's
        # backend runs on the mesh — so bad combinations fail at session
        # creation rather than at the first query.
        self._aggregation = (cfg.aggregation if aggregation is None
                             else aggregation)
        bsp.resolve_aggregation(
            self._aggregation, plan.model.kind,
            exchange=self._exchange.name
            if getattr(self._executor, "needs_block_shards", False) else None)
        self.lam = lam
        self.theta = theta
        self.adapt_every = int(adapt_every)
        self.accuracy_fn = accuracy_fn
        self.seed = cfg.seed if seed is None else seed
        # Mutable scheduler state starts from a COPY of the frozen plan's
        # placement and latency models: adaptation (which migrates vertices
        # AND updates the online load factor eta in place) must never write
        # through to the plan, or sibling sessions would see it.
        self.state = SchedulerState(placement=dataclasses.replace(
            plan.placement,
            assignment=np.array(plan.placement.assignment, copy=True)))
        self.fogs = [dataclasses.replace(
            f, latency_model=dataclasses.replace(
                f.latency_model, beta=np.array(f.latency_model.beta)))
            for f in plan.fogs]
        self.num_queries = 0
        self._partitioned = plan.partitioned  # valid for the initial layout
        # Stale-tolerant serving (exchange="halo_async"): the session may
        # replay recorded halo tables for up to staleness_bound serves
        # after a fresh synchronous pass. bound=0 (the default) keeps the
        # store off entirely — every serve runs the fresh path, which for
        # halo_async is the cached "halo" program (bit-identical).
        bound = (cfg.staleness_bound if staleness_bound is None
                 else int(staleness_bound))
        if bound < 0:
            raise ValueError(f"staleness_bound must be >= 0, got {bound}")
        if bound > 0 and not getattr(self._exchange, "stale_tolerant",
                                     False):
            raise ValueError(
                f"staleness_bound={bound} needs a stale-tolerant exchange "
                f"(e.g. 'halo_async'), got {self._exchange.name!r}")
        if bound > 0 and activation_cache:
            raise ValueError(
                "activation_cache and staleness_bound > 0 are mutually "
                "exclusive: the incremental frontier path assumes every "
                "serve's exchange is fresh")
        self._halo = _HaloStore(bound) if bound > 0 else None
        #: staleness (in serves since the last fresh exchange) of the most
        #: recent execute: 0 = fresh/synchronous. Recorded per response by
        #: the Server/FleetServer front-ends.
        self.last_staleness = 0
        self._acache = (_frontier.ActivationCache(frontier_max_fraction)
                        if activation_cache else None)
        #: QueryFrontier of the last query when it took the incremental
        #: path, else None (introspection for tests and benchmarks).
        self.last_frontier: Optional[_frontier.QueryFrontier] = None
        self._executor.check(plan)

    # -- runtime ------------------------------------------------------------

    @property
    def placement(self):
        """The session's *current* (possibly adapted) placement."""
        return self.state.placement

    def _needs_block_shards(self, backend: ExecutorBackend) -> bool:
        """Whether ``backend`` will read the per-shard block-CSR operands."""
        return (getattr(backend, "needs_block_shards", False)
                and bsp.resolve_aggregation(
                    self._aggregation, self.plan.model.kind,
                    exchange=self._exchange.name) == "pallas")

    def partitioned(self, backend: Optional[ExecutorBackend] = None
                    ) -> bsp.PartitionedGraph:
        """Static-shape buffers for the current assignment (cached).

        The block-CSR shards of the kernel aggregation path are built on
        demand: if the (given or session) backend needs them and the
        cached buffers lack them, the layout is rebuilt once with blocks.
        """
        backend = self._executor if backend is None else backend
        need = self._needs_block_shards(backend)
        pg = self._partitioned
        if pg is None or (need and pg.local_csr is None):
            self._partitioned = pg = bsp.build_partitioned(
                self.plan.graph, self.state.placement.assignment,
                build_blocks=need)
        return pg

    # -- separately callable query stages -----------------------------------

    def resolve_executor(self, executor=None) -> ExecutorBackend:
        """Per-query backend override -> checked ExecutorBackend."""
        if executor is None:
            return self._executor
        if isinstance(executor, ExecutorBackend):
            return executor   # already resolved (and checked) upstream
        backend = EXECUTORS.resolve(executor)
        if backend is not self._executor:
            backend.check(self.plan)
        return backend

    def collect(self, features: Optional[np.ndarray] = None) -> np.ndarray:
        """Stage 1 (paper step 3): compressed collection round-trip.

        ``features`` overrides the graph's stored features (fresh sensor
        uploads); the returned array carries the codec's true quantization
        error, exactly as the fogs would observe it after unpack.
        """
        g: Graph = self.plan.graph
        raw = g.features if features is None else np.asarray(features)
        return self._compressor.roundtrip(raw, g.degrees)

    def execute(self, feats: np.ndarray, *, executor=None) -> np.ndarray:
        """Stage 2 (paper step 4): distributed runtime (real numerics).

        With ``activation_cache=True`` this is where the incremental path
        lives: the collected ``feats`` are diffed bitwise against the
        cached h^0, the dirty frontier is expanded, and the executor
        recomputes only the dirty rows — or runs a full capturing pass
        when the cache cannot serve (always bit-identical either way).
        """
        backend = self.resolve_executor(executor)
        if self._acache is not None:
            return self._cached_execute(np.asarray(feats, np.float32),
                                        backend)
        if self._halo is not None:
            return self._stale_execute(np.asarray(feats, np.float32),
                                       backend, many=False)
        self.last_staleness = 0
        return backend.run(self.plan, feats, self.state.placement.assignment,
                           self.partitioned(backend), self._exchange.name,
                           aggregation=self._aggregation)

    def execute_many(self, feats, *, executor=None) -> list:
        """Batched stage 2 over a micro-batch ([B, V, F] stack or a
        sequence of [V, F] arrays) -> list of [V, D] embeddings.

        The Server's micro-batcher calls this instead of the backend's
        ``run_many`` directly so a cache-enabled session can serve the
        whole batch through ONE stacked frontier pass (the per-example
        h^0 diffs union into one dirty set; every member stays
        bit-identical to its serial ``execute``).
        """
        backend = self.resolve_executor(executor)
        if not (isinstance(feats, np.ndarray) and feats.ndim == 3):
            feats = np.stack([np.asarray(f, np.float32) for f in feats])
        feats = np.asarray(feats, np.float32)
        if self._acache is None:
            if self._halo is not None:
                return self._stale_execute(feats, backend, many=True)
            self.last_staleness = 0
            return backend.run_many(
                self.plan, feats, self.state.placement.assignment,
                self.partitioned(backend), self._exchange.name,
                aggregation=self._aggregation)
        if feats.shape[0] == 1:
            return [self._cached_execute(feats[0], backend)]
        return self._cached_execute(feats, backend)

    def _stale_execute(self, feats: np.ndarray, backend: ExecutorBackend,
                       many: bool):
        """Serve one execute under the stale-tolerant halo policy.

        A serve is stale when tables are recorded for the current graph
        revision and the store is younger than the bound: the mesh
        backend then replays the recorded boundary rows with NO per-layer
        collective (local rows still read the CURRENT query features),
        single-program backends serve plainly (they have no exchange to
        skip; the accounting is identical). Otherwise the serve is fresh:
        the mesh backend runs a capturing pass and the per-layer INPUT
        activations become the next tables.
        """
        store = self._halo
        plan = self.plan
        assign = self.state.placement.assignment
        pg = self.partitioned(backend)
        agg = self._aggregation
        revision = ops.graph_fingerprint(plan.graph)
        mesh = backend.supports_stale_halo(plan, agg)
        recorded = (store.tables is not None
                    and (store.tables != () if mesh
                         else store.tables == ()))
        if (recorded and store.revision == revision
                and store.age + 1 <= store.bound):
            store.age += 1
            self.last_staleness = store.age
            if not mesh:
                # Single-program numerics: no exchange, plain serve.
                if many:
                    return backend.run_many(plan, feats, assign, pg,
                                            self._exchange.name,
                                            aggregation=agg)
                return backend.run(plan, feats, assign, pg,
                                   self._exchange.name, aggregation=agg)
            if many:
                return backend.run_stale_many(plan, feats, assign, pg,
                                              store.tables,
                                              aggregation=agg)
            return backend.run_stale(plan, feats, assign, pg, store.tables,
                                     aggregation=agg)
        # Fresh serve: run synchronously and (re)record the tables.
        store.age = 0
        store.revision = revision
        self.last_staleness = 0
        if not mesh:
            store.tables = ()   # marker: accounting only, nothing to replay
            if many:
                return backend.run_many(plan, feats, assign, pg,
                                        self._exchange.name,
                                        aggregation=agg)
            return backend.run(plan, feats, assign, pg,
                               self._exchange.name, aggregation=agg)
        layers = backend.run_layers(plan, feats, assign, pg,
                                    self._exchange.name, aggregation=agg)
        # Layer l's halo table holds layer l's INPUT activations (the
        # features for l=0); a stacked batch records the LAST example,
        # matching the activation cache's merge convention.
        if many:
            inputs = [feats[-1]] + [np.asarray(a[-1])
                                    for a in layers[:-1]]
        else:
            inputs = [feats] + [np.asarray(a) for a in layers[:-1]]
        store.tables = bsp.build_halo_tables(pg, inputs)
        if many:
            return [np.asarray(e) for e in layers[-1]]
        return np.asarray(layers[-1])

    def _cached_execute(self, feats: np.ndarray, backend: ExecutorBackend):
        """Serve one execute through the activation cache.

        ``feats`` is [V, F] (returns [V, D]) or a stacked [B, V, F]
        micro-batch (returns a list of B [V, D] arrays). Decision order:
        tag agreement (graph revision + aggregation mode + executor
        family) -> h^0 diff + frontier expansion -> empty-frontier fast
        path / budgeted incremental pass / full capturing pass.
        """
        cache = self._acache
        plan = self.plan
        g: Graph = plan.graph
        k = plan.model.num_layers
        assign = self.state.placement.assignment
        pg = self.partitioned(backend)
        exch = self._exchange.name
        agg = self._aggregation
        stacked = feats.ndim == 3
        mode = bsp.resolve_aggregation(
            agg, plan.model.kind,
            exchange=exch if getattr(backend, "needs_block_shards", False)
            else None)
        family = getattr(backend, "frontier_family", "single")
        revision = ops.graph_fingerprint(g)
        self.last_frontier = None
        if cache.matches(revision, mode, family):
            qf = cache.plan_query(feats, g, k)
            if qf is not None and not len(qf.rows):
                # Nothing changed since the cached pass: serve the cached
                # final layer outright (sound for every kind, GAT too).
                if stacked:
                    return [np.array(cache.layers[-1], copy=True)
                            for _ in range(feats.shape[0])]
                return np.array(cache.layers[-1], copy=True)
            if (qf is not None
                    and backend.supports_frontier(plan, agg)
                    and (mode != "pallas" or cache.pallas_ok)):
                emb, merged = backend.run_frontier(
                    plan, feats, assign, pg, exch, agg, qf.rows,
                    cache.layers)
                # A stacked pass merges the LAST example's tables: its
                # h^0 becomes the diff baseline, and any member-specific
                # rows self-correct through the next query's diff.
                if stacked:
                    cache.merge(feats[-1], [m[-1] for m in merged])
                else:
                    cache.merge(feats, merged)
                self.last_frontier = qf
                return emb
        # Full pass, capturing every layer to (re)base the cache.
        try:
            layers = backend.run_layers(plan, feats, assign, pg, exch,
                                        aggregation=agg)
        except NotImplementedError:
            # Backend cannot capture: serve plainly, cache stays cold.
            cache.clear()
            if stacked:
                return backend.run_many(plan, feats, assign, pg, exch,
                                        aggregation=agg)
            return backend.run(plan, feats, assign, pg, exch,
                               aggregation=agg)
        if stacked:
            cache.populate(feats[-1], [a[-1] for a in layers],
                           revision, mode, family)
            return [np.asarray(e) for e in layers[-1]]
        cache.populate(feats, layers, revision, mode, family)
        return np.asarray(layers[-1])

    def account(self, executor=None, *, batch_size: int = 1,
                staleness: Optional[int] = None) -> simulation.ServingResult:
        """Stage 3: simulated latency pricing for the current placement.

        ``batch_size`` prices a micro-batch of coalesced queries (used by
        the Server front-end; 1 = one query). ``staleness`` prices the
        serve's exchange mode: a stale halo_async serve (staleness > 0)
        never stalls a superstep on the exchange, so the K*delta sync
        term drops out of the multi-fog pipeline (``sync_scale=0``);
        None reads the session's ``last_staleness``.
        """
        backend = self.resolve_executor(executor)
        if staleness is None:
            staleness = self.last_staleness
        scale = 0.0 if staleness else 1.0
        return simulation.simulate(backend.pipeline, self.plan.cluster,
                                   self.state.placement,
                                   compress=self._compressor.sim_key,
                                   batch_size=batch_size,
                                   sync_scale=scale)

    def exchange_bytes(self, executor=None, *,
                       staleness: Optional[int] = None) -> int:
        """Per-BSP-sync collective payload (0 off the multi-fog pipeline).

        Accounts for the wire format the backend actually ships: float32
        rows on the segment-sum path, uint8 codes + one (scale, min) pair
        per row when the mesh backend's DAQ-fused kernel path is active.
        A stale halo_async serve replays recorded tables and ships
        NOTHING over the wire (``staleness`` as in ``account``).
        """
        backend = self.resolve_executor(executor)
        if backend.pipeline != "multi":
            return 0
        if staleness is None:
            staleness = self.last_staleness
        if staleness:
            return 0
        dtype_bytes, row_overhead = backend.wire_format(
            self.plan, self._exchange.name, self._aggregation)
        return self._exchange.bytes_per_sync(self.partitioned(),
                                             self.plan.graph.feature_dim,
                                             dtype_bytes, row_overhead)

    def tick(self) -> None:
        """Count one served query and run the ``adapt_every`` schedule."""
        self.num_queries += 1
        if self.adapt_every and self.num_queries % self.adapt_every == 0:
            self.adapt()

    def query(self, features: Optional[np.ndarray] = None, *,
              executor: Optional[str] = None) -> QueryResult:
        """Serve one inference query (steps 3-4 of the paper's workflow).

        ``features`` overrides the graph's stored features for this query
        (fresh sensor uploads); ``executor`` overrides the backend for this
        query only.
        """
        backend = self.resolve_executor(executor)
        feats = self.collect(features)
        emb = self.execute(feats, executor=backend)
        res = self.account(backend)
        breakdown = dict(res.breakdown())
        breakdown["unpack"] = float(res.unpack.max())
        xbytes = self.exchange_bytes(backend)
        acc = None if self.accuracy_fn is None else float(
            self.accuracy_fn(emb))
        out = QueryResult(embeddings=emb, latency=res.total_latency,
                          throughput=res.throughput, breakdown=breakdown,
                          wire_bytes=res.wire_bytes, exchange_bytes=xbytes,
                          backend=backend.name, accuracy=acc)
        # step 5: adaptive scheduling tick, owned by the session.
        self.tick()
        return out

    def stream(self, queries: Union[int, Iterable], *,
               executor: Optional[str] = None) -> Iterator[QueryResult]:
        """Deprecated: serve queries one at a time (use ``Server.replay``).

        ``queries`` is either a count (re-serve the stored features) or an
        iterable of feature arrays (None entries use stored features).
        ``executor`` overrides the backend for every query in the stream.
        Kept as a thin lazy shim over the request-level ``Server.replay``
        with batching and pipelining disabled: one query is served per
        ``next()``, and per-query latency/throughput/embeddings match the
        historical serial loop exactly (the Response ``breakdown`` reports
        the server's collect/execute *stage* split rather than the
        bottleneck-fog split of ``Session.query``).
        """
        warnings.warn(
            "Session.stream is deprecated; use repro.api.Server — "
            "plan.server().replay(...) — for request-level serving with "
            "micro-batching and pipelined collect/execute",
            DeprecationWarning, stacklevel=2)
        from repro.api.server import Server
        server = Server(self, max_batch=1, pipelined=False)
        if isinstance(queries, int):
            queries = (None for _ in range(queries))
        for q in queries:   # lazily: serve one request per next()
            yield server.replay([q], executor=executor)[0]

    # -- dynamic-graph updates ----------------------------------------------

    @property
    def pending_updates(self) -> int:
        """Buffered deltas awaiting a flush (always 0 under "sync")."""
        return len(self._pending_deltas)

    def update(self, delta: GraphDelta) -> Optional[UpdateReport]:
        """Absorb one graph mutation (the serving-time update stage).

        Under the "sync" policy the delta is applied immediately and the
        report returned; under "deferred" it is buffered (returns None)
        until ``flush_updates`` coalesces the whole buffer into a single
        repair.  Deferred deltas address the graph produced by the
        previous delta in the buffer, not the session's current graph.
        """
        if not isinstance(delta, GraphDelta):
            raise TypeError("update() takes a GraphDelta, got "
                            f"{type(delta).__name__}")
        # Fail fast at admission: a delta whose ids cannot be valid
        # against the projected graph must not enter the buffer, or a
        # later deferred flush would keep tripping over it.
        v, f = self._projected_shape
        delta.validate(v, f)
        v_next = (v - delta.num_removed_vertices
                  + delta.num_added_vertices)
        if v_next < self.plan.num_fogs:
            raise ValueError(
                f"delta leaves {v_next} vertices for "
                f"{self.plan.num_fogs} fog partitions")
        self._pending_deltas.append(delta)
        self._projected_shape = (v_next, f)
        if self.update_policy != "sync":
            return None
        try:
            return self.flush_updates()
        except BaseException:
            # The rejected delta never happened: drop it (flush_updates
            # restored the buffer) so later updates aren't blocked.
            self._pending_deltas.pop()
            self._projected_shape = (v, f)
            raise

    def flush_updates(self) -> Optional[UpdateReport]:
        """Apply every buffered delta in one coalesced repair.

        Rebases the session onto the updated plan: the repair starts from
        the session's *current* (possibly adapted) assignment, the
        scheduler state keeps its history/eta but re-anchors on the
        repaired placement, and cached partition buffers swap for the
        incrementally rebuilt ones.  Returns None when nothing is pending.
        """
        if not self._pending_deltas:
            return None
        from repro.api.engine import Engine   # lazy: avoid import cycle
        deltas, self._pending_deltas = self._pending_deltas, []
        old_graph = self.plan.graph
        try:
            plan2 = Engine.from_plan(self.plan).apply_delta(
                self.plan, deltas,
                assignment=self.state.placement.assignment)
        except BaseException:
            # Keep the buffer intact so a bad delta can be inspected or
            # dropped without losing its neighbours.
            self._pending_deltas = deltas + self._pending_deltas
            raise
        if self._acache is not None and self._acache.primed:
            # Remap the cached activations through the coalesced repair's
            # order-preserving compaction and record the dirty seeds; any
            # disagreement with the repaired plan drops the cache instead
            # of risking a stale serve.
            try:
                fu = _frontier.fold_delta_frontier(old_graph, deltas)
            except Exception:
                self._acache.clear()
            else:
                rev = ops.graph_fingerprint(plan2.graph)
                if ops.graph_fingerprint(fu.graph) == rev:
                    self._acache.apply_update(fu, revision=rev)
                else:
                    self._acache.clear()
        if self._halo is not None:
            # An applied update bumps the data version: recorded halo
            # tables predate it (and the repair may have changed the
            # partition layout), so the next serve must be fresh.
            self._halo.invalidate()
        self.plan = plan2
        self.state.placement = dataclasses.replace(
            plan2.placement,
            assignment=np.array(plan2.placement.assignment, copy=True))
        self._partitioned = plan2.partitioned
        self._projected_shape = (plan2.graph.num_vertices,
                                 plan2.graph.feature_dim)
        return plan2.update_report

    # -- node-level fault tolerance ------------------------------------------

    def can_serve_stale(self) -> bool:
        """Whether the NEXT execute could ride through on recorded halo
        tables (tier-2 fault recovery): a store exists, tables are
        recorded for the current graph revision, and one more stale
        serve stays within the bound."""
        store = self._halo
        if store is None or store.tables is None:
            return False
        mesh = self._executor.supports_stale_halo(self.plan,
                                                  self._aggregation)
        recorded = (store.tables != () if mesh else store.tables == ())
        return (recorded
                and store.revision == ops.graph_fingerprint(self.plan.graph)
                and store.age + 1 <= store.bound)

    def rebind(self, plan2) -> None:
        """Rebase this session onto ``plan2`` (same graph, new layout).

        The failover/recovery rebase: scheduler state re-anchors on the
        new placement, profiled fog models swap for the new plan's, halo
        tables invalidate (they are laid out per the old partitioning)
        and mesh-family activation caches clear (single-program numerics
        are assignment-independent, so those survive). Mirrors the
        ``flush_updates`` rebase, minus the graph change.
        """
        if plan2.graph.num_vertices != self.plan.graph.num_vertices:
            raise ValueError(
                "rebind() is a same-graph rebase; use update()/"
                "flush_updates() for graph mutations")
        if self._halo is not None:
            self._halo.invalidate()
        if self._acache is not None and self._acache.family == "mesh":
            self._acache.clear()
        self.plan = plan2
        self.state.placement = dataclasses.replace(
            plan2.placement,
            assignment=np.array(plan2.placement.assignment, copy=True))
        self.fogs = [dataclasses.replace(
            f, latency_model=dataclasses.replace(
                f.latency_model, beta=np.array(f.latency_model.beta)))
            for f in plan2.fogs]
        self._partitioned = plan2.partitioned

    def failover(self, crashed, *, mode: Optional[str] = None):
        """Tier-3 recovery: evict ``crashed`` node(s), re-place their
        shards onto the survivors (``Engine.fail_nodes``) and rebase this
        session onto the degraded-capacity failover plan. Queries keep
        flowing — on partition-independent numerics they stay
        bit-identical to the pre-crash serves. Returns the new plan.
        """
        from repro.api.engine import Engine   # lazy: avoid import cycle
        plan2 = Engine.from_plan(self.plan).fail_nodes(
            self.plan, crashed,
            assignment=self.state.placement.assignment, mode=mode)
        self.rebind(plan2)
        return plan2

    # -- adaptation ---------------------------------------------------------

    def adapt(self, *, lam: Optional[float] = None,
              theta: Optional[float] = None,
              seed: Optional[int] = None) -> str:
        """One adaptive-scheduler tick (Alg. 2); returns the action taken."""
        plan = self.plan
        t_real = simulation.measured_exec_times(plan.cluster,
                                                self.state.placement)
        before = self.state.placement.assignment
        self.state = schedule_step(
            plan.graph, self.state, self.fogs, t_real,
            lam=self.lam if lam is None else lam,
            theta=self.theta if theta is None else theta,
            k_layers=plan.model.num_layers,
            sync_cost=plan.cluster.sync_cost,
            bytes_per_vertex=plan.config.bytes_per_vertex,
            seed=self.seed if seed is None else seed,
            replan_strategy=plan.config.placement,
            replan_partitioner=PARTITIONERS.resolve(plan.config.partitioner))
        if not np.array_equal(before, self.state.placement.assignment):
            self._partitioned = None  # layout changed: invalidate buffers
            if self._halo is not None:
                # Recorded tables are laid out per the old partitioning.
                self._halo.invalidate()
            if self._acache is not None and self._acache.family == "mesh":
                # Mesh-family cached tables were produced under the old
                # partition's halo layout; single-program numerics are
                # assignment-independent so those caches survive.
                self._acache.clear()
        return self.state.mode_history[-1]

    # -- frontier introspection ---------------------------------------------

    def frontier_state(self) -> Optional["_frontier.FrontierPlan"]:
        """Snapshot of the pending dirty frontier for ``repro.analysis``
        (None when the session has no activation cache or a cold one)."""
        if self._acache is None:
            return None
        return self._acache.frontier_plan(self.plan.graph,
                                          self.plan.model.num_layers)
