"""Serving sessions: repeated/batched queries over a compiled Plan.

A ``Session`` owns every piece of mutable runtime state the old
``FographService`` grab-bag mixed into one dataclass:

  * the adaptive scheduler's ``SchedulerState`` (placement drift, mode
    history) — seeded from a *copy* of the plan's placement, so the plan
    itself stays frozen,
  * the partitioned-buffer cache (rebuilt only when adaptation migrates
    vertices),
  * query counters for the ``adapt_every`` tick.

Every query returns a ``QueryResult`` with one unified metrics schema
across executor backends (sim / single / mesh-bsp).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Iterator, Optional, Union

import numpy as np

from repro.api import executors as _executors  # noqa: F401  (registers backends)
from repro.api.registry import (COMPRESSORS, EXCHANGES, EXECUTORS,
                                PARTITIONERS)
from repro.core import simulation
from repro.core.scheduler import SchedulerState, schedule_step
from repro.gnn.graph import Graph
from repro.runtime import bsp


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Unified per-query metrics, identical across executor backends.

    ``breakdown`` keys: collect / execute / unpack / total (seconds, for
    the bottleneck fog). ``exchange_bytes`` is the per-BSP-sync collective
    payload under the plan's exchange strategy (0 for the single backend,
    which has no cross-fog sync). ``accuracy`` is filled by the session's
    ``accuracy_fn`` hook when one is installed.
    """
    embeddings: np.ndarray
    latency: float
    throughput: float
    breakdown: Dict[str, float]
    wire_bytes: float
    exchange_bytes: int
    backend: str
    accuracy: Optional[float] = None


class Session:
    """Live serving handle for one Plan: ``query``, ``stream``, ``adapt``."""

    def __init__(self, plan, *, executor: Optional[str] = None,
                 lam: float = 1.3, theta: float = 0.5,
                 adapt_every: int = 0,
                 accuracy_fn: Optional[Callable[[np.ndarray], float]] = None,
                 seed: Optional[int] = None):
        self.plan = plan
        cfg = plan.config
        self._executor_key = cfg.executor if executor is None else executor
        self._executor = EXECUTORS.resolve(self._executor_key)
        self._compressor = COMPRESSORS.resolve(cfg.compressor)
        self._exchange = EXCHANGES.resolve(cfg.exchange)
        self.lam = lam
        self.theta = theta
        self.adapt_every = int(adapt_every)
        self.accuracy_fn = accuracy_fn
        self.seed = cfg.seed if seed is None else seed
        # Mutable scheduler state starts from a COPY of the frozen plan's
        # placement and latency models: adaptation (which migrates vertices
        # AND updates the online load factor eta in place) must never write
        # through to the plan, or sibling sessions would see it.
        self.state = SchedulerState(placement=dataclasses.replace(
            plan.placement,
            assignment=np.array(plan.placement.assignment, copy=True)))
        self.fogs = [dataclasses.replace(
            f, latency_model=dataclasses.replace(
                f.latency_model, beta=np.array(f.latency_model.beta)))
            for f in plan.fogs]
        self.num_queries = 0
        self._partitioned = plan.partitioned  # valid for the initial layout
        self._executor.check(plan)

    # -- runtime ------------------------------------------------------------

    @property
    def placement(self):
        """The session's *current* (possibly adapted) placement."""
        return self.state.placement

    def partitioned(self) -> bsp.PartitionedGraph:
        """Static-shape buffers for the current assignment (cached)."""
        if self._partitioned is None:
            self._partitioned = bsp.build_partitioned(
                self.plan.graph, self.state.placement.assignment)
        return self._partitioned

    def query(self, features: Optional[np.ndarray] = None, *,
              executor: Optional[str] = None) -> QueryResult:
        """Serve one inference query (steps 3-4 of the paper's workflow).

        ``features`` overrides the graph's stored features for this query
        (fresh sensor uploads); ``executor`` overrides the backend for this
        query only.
        """
        plan = self.plan
        g: Graph = plan.graph
        backend = (self._executor if executor is None
                   else EXECUTORS.resolve(executor))
        if backend is not self._executor:
            backend.check(plan)
        # step 3: compressed collection (real pack/unpack round-trip).
        raw = g.features if features is None else np.asarray(features)
        feats = self._compressor.roundtrip(raw, g.degrees)
        # step 4: distributed runtime (real numerics).
        emb = backend.run(plan, feats, self.state.placement.assignment,
                          self.partitioned(), self._exchange.name)
        # latency accounting from the simulated fog cluster.
        res = simulation.simulate(backend.pipeline, plan.cluster,
                                  self.state.placement,
                                  compress=self._compressor.sim_key)
        breakdown = dict(res.breakdown())
        breakdown["unpack"] = float(res.unpack.max())
        if backend.pipeline == "multi":
            xbytes = self._exchange.bytes_per_sync(self.partitioned(),
                                                   g.feature_dim)
        else:
            xbytes = 0
        acc = None if self.accuracy_fn is None else float(
            self.accuracy_fn(emb))
        self.num_queries += 1
        out = QueryResult(embeddings=emb, latency=res.total_latency,
                          throughput=res.throughput, breakdown=breakdown,
                          wire_bytes=res.wire_bytes, exchange_bytes=xbytes,
                          backend=backend.name, accuracy=acc)
        # step 5: adaptive scheduling tick, owned by the session.
        if self.adapt_every and self.num_queries % self.adapt_every == 0:
            self.adapt()
        return out

    def stream(self, queries: Union[int, Iterable]) -> Iterator[QueryResult]:
        """Serve a batch of queries; yields one QueryResult each.

        ``queries`` is either a count (re-serve the stored features) or an
        iterable of feature arrays (None entries use stored features).
        """
        if isinstance(queries, int):
            queries = (None for _ in range(queries))
        for feats in queries:
            yield self.query(feats)

    # -- adaptation ---------------------------------------------------------

    def adapt(self, *, lam: Optional[float] = None,
              theta: Optional[float] = None,
              seed: Optional[int] = None) -> str:
        """One adaptive-scheduler tick (Alg. 2); returns the action taken."""
        plan = self.plan
        t_real = simulation.measured_exec_times(plan.cluster,
                                                self.state.placement)
        before = self.state.placement.assignment
        self.state = schedule_step(
            plan.graph, self.state, self.fogs, t_real,
            lam=self.lam if lam is None else lam,
            theta=self.theta if theta is None else theta,
            k_layers=plan.model.num_layers,
            sync_cost=plan.cluster.sync_cost,
            bytes_per_vertex=plan.config.bytes_per_vertex,
            seed=self.seed if seed is None else seed,
            replan_strategy=plan.config.placement,
            replan_partitioner=PARTITIONERS.resolve(plan.config.partitioner))
        if not np.array_equal(before, self.state.placement.assignment):
            self._partitioned = None  # layout changed: invalidate buffers
        return self.state.mode_history[-1]
