"""String-keyed component registries for the Fograph serving pipeline.

Every pluggable stage of the paper's workflow (Fig. 5/6) resolves through
one of five registries, so scenarios are wired by *key*, not by code:

  PARTITIONERS  graph -> balanced partitions        ("bgp")
  PLACEMENTS    partitions -> fog mapping           ("iep", "metis+greedy",
                                                     "random")
  COMPRESSORS   device upload codec                 ("daq", "uniform8",
                                                     "none", ...)
  EXCHANGES     per-layer BSP cross-fog exchange    ("halo", "allgather")
  EXECUTORS     runtime backend                     ("sim", "single",
                                                     "mesh-bsp")

This module is intentionally a leaf: it imports nothing from the rest of
``repro`` so that core modules can register themselves without cycles.
Implementations live next to the algorithms they wrap (``core.partition``,
``core.placement``, ``core.compression``, ``runtime.bsp``,
``api.executors``) and register at import time.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional


class UnknownComponentError(KeyError):
    """Raised when a registry key does not resolve; message lists the
    registry's name, every available key, and a close-match suggestion."""

    def __init__(self, kind: str, key: str, available, aliases=()):
        self.kind = kind
        self.key = key
        self.available = tuple(sorted(available))
        msg = (f"unknown {kind} {key!r}; available: "
               f"{', '.join(self.available) or '(none registered)'}")
        import difflib
        close = difflib.get_close_matches(
            key, [*self.available, *aliases], n=1, cutoff=0.6)
        if close:
            msg += f" (did you mean {close[0]!r}?)"
        super().__init__(msg)

    def __str__(self) -> str:  # KeyError.__str__ would repr() the message
        return self.args[0]


class Registry:
    """A named string -> component mapping with helpful resolution errors."""

    def __init__(self, kind: str, aliases: Optional[Dict[str, str]] = None):
        self.kind = kind
        self._entries: Dict[str, Any] = {}
        self._aliases: Dict[str, str] = dict(aliases or {})

    def register(self, key: str, value: Any = None) -> Any:
        """Register ``value`` under ``key``; usable as a decorator."""
        if value is None:
            return lambda v: self.register(key, v)
        self._entries[key] = value
        return value

    def alias(self, alias: str, target: str) -> None:
        self._aliases[alias] = target

    def canonical(self, key: str) -> str:
        return self._aliases.get(key, key)

    def resolve(self, key: Any) -> Any:
        """Resolve a registry key to its component.

        Non-string values pass through unchanged, so call sites accept
        either a key or an already-constructed component.
        """
        if not isinstance(key, str):
            return key
        k = self.canonical(key)
        if k not in self._entries:
            raise UnknownComponentError(self.kind, key, self._entries,
                                        aliases=self._aliases)
        return self._entries[k]

    def keys(self):
        return sorted(self._entries)

    def __contains__(self, key: str) -> bool:
        return isinstance(key, str) and self.canonical(key) in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.keys())

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, keys={self.keys()})"


PARTITIONERS = Registry("partitioner")
PLACEMENTS = Registry("placement strategy",
                      aliases={"greedy": "metis+greedy",
                               "metis+random": "random"})
COMPRESSORS = Registry("compressor", aliases={"null": "none"})
EXCHANGES = Registry("exchange")
EXECUTORS = Registry("executor backend", aliases={"bsp": "mesh-bsp",
                                                  "simulate": "sim"})

ALL_REGISTRIES = {
    "partitioner": PARTITIONERS,
    "placement": PLACEMENTS,
    "compressor": COMPRESSORS,
    "exchange": EXCHANGES,
    "executor": EXECUTORS,
}
