"""Throughput benchmark: request-level serving under arrival traces.

Sweeps micro-batch cap x arrival rate x executor backend for the
``repro.api.Server`` front-end against the serial ``Session.stream``
baseline (max_batch=1, pipelining off) on the *same* Poisson trace, and
writes the whole trajectory to ``BENCH_throughput.json``.

This is the reproduction's arrival-driven counterpart of the paper's
streaming evaluation (§III-D pipelined collection, Fig. 9 throughput):
the win comes from (a) coalescing compatible requests into one batched
collect + one executor run (one long-tail window, one packing overhead,
one K*delta sync round per batch) and (b) overlapping batch k+1's
collection with batch k's execution.

    PYTHONPATH=src python benchmarks/throughput.py            # full sweep
    PYTHONPATH=src python benchmarks/throughput.py --smoke    # CI guard
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(REPO, "src", "repro")):
    sys.path.insert(0, os.path.join(REPO, "src"))


def build_plan(args):
    import jax

    from repro.api import Engine
    from repro.gnn import datasets, models

    graph = datasets.load(args.dataset, scale=args.scale, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), args.kind,
                             [graph.feature_dim, args.hidden, 8])
    engine = Engine((params, args.kind), cluster=args.cluster,
                    network=args.network, compressor=args.compressor)
    return engine.compile(graph), graph


def make_trace(args, rate: float, seed: int):
    from repro.api import traces
    gen = {"poisson": traces.poisson, "constant": traces.constant,
           "bursty": traces.bursty}[args.trace]
    return gen(args.requests, rate, seed=seed)


def run_config(plan, trace, *, executor: str, max_batch: int,
               max_wait: float, pipelined: bool = True) -> dict:
    server = plan.server(max_batch=max_batch, max_wait=max_wait,
                         pipelined=pipelined, executor=executor)
    t0 = time.perf_counter()
    responses = server.replay(list(trace))
    wall = time.perf_counter() - t0
    out = server.summarize(responses)
    out["wall_s"] = wall
    return out


def measure_executor_batching(plan, graph, executors, batch: int,
                              repeats: int = 5) -> list:
    """Batched ``run_many`` vs the serial ``run`` loop per executor.

    This is the *executor-dispatch* term of the batching win (PR 5: one
    fused traced call for the whole micro-batch), measured standalone so
    the trajectory records it separately from the simulated-clock
    pipeline speedup the sweep above reports. The measurement itself
    (incl. the bit-identity assertion) lives in
    ``benchmarks/serving_latency.py`` — shared so the two cannot drift.
    """
    import numpy as np

    import serving_latency

    from repro.api.registry import EXECUTORS

    rng = np.random.default_rng(0)
    feats = [(graph.features + rng.normal(
        scale=0.01, size=graph.features.shape)).astype(np.float32)
        for _ in range(batch)]
    out = []
    for executor in executors:
        backend = EXECUTORS.resolve(executor)
        for agg in serving_latency.supported_aggregations(
                plan, ["segment_sum", "pallas"]):
            row = serving_latency.time_batched_vs_serial(
                backend, plan, feats, agg, repeats)
            assert row["bit_identical"], (executor, agg)
            out.append(row)
            print(f"executor-batching {executor}/{agg}: B={batch} "
                  f"serial={row['serial_s'] * 1e3:.1f}ms "
                  f"batched={row['batched_s'] * 1e3:.1f}ms "
                  f"({row['speedup']:.2f}x, bit-identical)")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + pass/fail guard (for scripts/ci.sh)")
    ap.add_argument("--out", default=os.path.join(REPO,
                                                  "BENCH_throughput.json"))
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--kind", default="gcn")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--cluster", default="1A+4B+1C")
    ap.add_argument("--network", default="wifi")
    ap.add_argument("--compressor", default="daq")
    ap.add_argument("--trace", default="poisson",
                    choices=["poisson", "constant", "bursty"])
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[2.0, 4.0, 8.0, 16.0])
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--executors", nargs="+",
                    default=["sim", "single", "cloud"])
    ap.add_argument("--max-wait", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        args.scale = 0.05
        args.requests = 16
        args.rates = [8.0]
        args.batches = [1, 4]
        args.executors = ["sim"]
        if args.out == ap.get_default("out"):   # don't dirty the worktree
            import tempfile
            args.out = os.path.join(tempfile.gettempdir(),
                                    "BENCH_throughput.smoke.json")

    plan, graph = build_plan(args)
    print(f"plan: |V|={graph.num_vertices} |E|={graph.num_edges} "
          f"cluster={args.cluster} trace={args.trace} "
          f"requests={args.requests}")

    sweep = []
    print("executor,rate,max_batch,pipelined,throughput_rps,"
          "latency_mean_s,latency_p95_s,mean_batch,speedup_vs_serial")
    for executor in args.executors:
        for rate in args.rates:
            trace = make_trace(args, rate, args.seed)
            # Serial Session.stream baseline: one request at a time, no
            # collect/execute overlap — same trace, same backend.
            serial = run_config(plan, trace, executor=executor, max_batch=1,
                                max_wait=0.0, pipelined=False)
            serial.update(executor=executor, rate=rate, max_batch=1,
                          pipelined=False, speedup_vs_serial=1.0)
            sweep.append(serial)
            for mb in args.batches:
                row = run_config(plan, trace, executor=executor,
                                 max_batch=mb, max_wait=args.max_wait)
                row.update(executor=executor, rate=rate, max_batch=mb,
                           pipelined=True,
                           speedup_vs_serial=serial["makespan_s"]
                           / max(row["makespan_s"], 1e-12))
                sweep.append(row)
                print(f"{executor},{rate},{mb},True,"
                      f"{row['throughput_rps']:.3f},"
                      f"{row['latency_mean_s']:.3f},"
                      f"{row['latency_p95_s']:.3f},"
                      f"{row['mean_batch']:.2f},"
                      f"{row['speedup_vs_serial']:.3f}")

    # Standalone executor-dispatch term: batched run_many vs the serial
    # run loop at the sweep's largest micro-batch (bit-identity asserted).
    # Full runs only — the CI smoke already covers this measurement via
    # benchmarks/serving_latency.py --smoke.
    exec_batching = []
    if not args.smoke:
        exec_batching = measure_executor_batching(
            plan, graph, args.executors, batch=max(max(args.batches), 2))

    payload = {
        "benchmark": "server_throughput",
        "config": {k: v for k, v in vars(args).items() if k != "smoke"},
        "graph": {"vertices": graph.num_vertices, "edges": graph.num_edges},
        "executor_batching": exec_batching,
        "sweep": sweep,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(sweep)} rows)")

    # Pipelined micro-batching must beat the serial loop wherever the
    # arrival rate actually stresses the pipeline (the acceptance guard).
    best = {}
    for row in sweep:
        key = (row["executor"], row["rate"])
        if row["pipelined"]:
            best[key] = max(best.get(key, 0.0), row["speedup_vs_serial"])
    worst = min(best.values())
    print(f"best pipelined speedup per (executor, rate): "
          f"min={worst:.3f} max={max(best.values()):.3f}")
    if worst <= 1.0:
        print("FAIL: pipelined server never beat the serial baseline")
        return 1
    print("PASS: pipelined micro-batching beats serial Session.stream")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
