"""Serving-latency benchmark: batched executor dispatch vs the serial loop.

Sweeps micro-batch size x aggregation path x executor backend for the
batch-axis ``run_many`` execution (PR 5 tentpole: one fused dispatch for
the whole micro-batch — the batch-grid Pallas kernels on the GCN/SAGE
kernel path, one vmapped program on the segment-sum/GAT path) against the
serial per-request ``run`` loop on identical feature batches, asserts the
two are bit-identical, and writes the sweep to ``BENCH_serving.json``.

Methodology: best-of-repeats wall-clock of the *steady state* (every
traced call warmed up first, so compile time is excluded — what remains
is per-request dispatch overhead plus the actual numerics; min rather
than median because background-load noise is strictly additive). Off-TPU the Pallas kernels
execute in interpret mode, so kernel-path times measure the interpreter,
not the MXU: the speedup columns quantify *dispatch amortization* — B
dispatches collapsing into one — which is exactly the term micro-batching
exists to kill, and transfers to hardware backends where the batched grid
additionally amortizes block-CSR operand loads across the batch (the
``block_cols`` table is scalar-prefetched once per launch). The default
graph scale keeps per-fog subgraphs at the paper's IoT sizes, where
dispatch overhead is a first-order serving cost.

    PYTHONPATH=src python benchmarks/serving_latency.py            # full sweep
    PYTHONPATH=src python benchmarks/serving_latency.py --smoke    # CI guard

The CI ``--smoke`` mode shrinks the sweep and fails (exit 1) unless every
batched result is bit-identical to its serial loop; the full run
additionally fails unless the kernel path shows >= 2x batched-over-serial
speedup at some B >= 8 (the PR acceptance criterion).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import timeit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(REPO, "src", "repro")):
    sys.path.insert(0, os.path.join(REPO, "src"))


def _best_time(fn, repeats: int) -> float:
    """Min wall-clock of ``fn()`` over ``repeats`` runs (pre-warmed).

    Min, not mean/median: scheduler and background-load noise is strictly
    additive, so the fastest observation is the best estimate of the
    work's intrinsic cost (the same reasoning as the ``timeit`` docs).
    """
    return min(timeit.repeat(fn, number=1, repeat=repeats))


def supported_aggregations(plan, requested) -> list:
    """Drop aggregation paths the plan's model kind cannot run (the
    kernel path is GCN/SAGE-only; requesting it for GAT would raise)."""
    from repro.runtime import bsp
    return [a for a in requested
            if a != "pallas" or plan.model.kind in bsp.KERNEL_KINDS]


def time_batched_vs_serial(backend, plan, feats, aggregation: str,
                           repeats: int) -> dict:
    """One measurement point: batched ``run_many`` vs the serial ``run``
    loop on the same feature batch, bit-identity asserted before timing.

    Shared by this sweep and ``benchmarks/throughput.py``'s
    ``executor_batching`` record so the two cannot drift.
    """
    import numpy as np

    assignment = plan.placement.assignment
    stacked = np.stack([np.asarray(f, np.float32) for f in feats])

    def serial():
        return [backend.run(plan, f, assignment, plan.partitioned, "halo",
                            aggregation=aggregation) for f in feats]

    def batched():
        return backend.run_many(plan, stacked, assignment, plan.partitioned,
                                "halo", aggregation=aggregation)

    ser = serial()           # warm-up (jit traces) + parity data
    bat = batched()
    ok = all(np.array_equal(x, y) for x, y in zip(bat, ser))
    t_serial = _best_time(serial, repeats)
    t_batched = _best_time(batched, repeats)
    b = len(feats)
    return {
        "executor": backend.name, "aggregation": aggregation, "batch": b,
        "serial_s": t_serial, "batched_s": t_batched,
        "serial_per_request_ms": t_serial / b * 1e3,
        "batched_per_request_ms": t_batched / b * 1e3,
        "speedup": t_serial / max(t_batched, 1e-12),
        "bit_identical": ok,
    }


def sweep(args) -> dict:
    import jax
    import numpy as np

    from repro.api import Engine
    from repro.api.registry import EXECUTORS
    from repro.gnn import datasets, models

    g = datasets.load(args.dataset, scale=args.scale, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), args.kind,
                             [g.feature_dim, args.hidden, 8])
    plan = Engine((params, args.kind), cluster=args.cluster,
                  compressor="none").compile(g)
    rng = np.random.default_rng(args.seed)
    rows = []
    parity_ok = True
    aggregations = supported_aggregations(plan, args.aggregations)
    for dropped in set(args.aggregations) - set(aggregations):
        print(f"note: skipping aggregation={dropped!r} "
              f"(unsupported for kind={args.kind!r})")
    for executor in args.executors:
        backend = EXECUTORS.resolve(executor)
        for agg in aggregations:
            for b in args.batches:
                feats = [(g.features + rng.normal(
                    scale=0.01, size=g.features.shape)).astype(np.float32)
                    for _ in range(b)]
                row = time_batched_vs_serial(backend, plan, feats, agg,
                                             args.repeats)
                parity_ok = parity_ok and row["bit_identical"]
                rows.append(row)
                print(f"{executor:>7} {agg:>12} B={b:<3d} "
                      f"serial={row['serial_s'] * 1e3:8.2f}ms "
                      f"batched={row['batched_s'] * 1e3:8.2f}ms "
                      f"speedup={row['speedup']:5.2f}x "
                      f"identical={row['bit_identical']}")
    return {
        "rows": rows, "parity_ok": parity_ok,
        "graph": {"vertices": g.num_vertices, "edges": g.num_edges,
                  "feature_dim": g.feature_dim},
    }


def main(argv=None) -> int:
    import numpy as np

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + bit-identity guard (scripts/ci.sh)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_serving.json"))
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.03)
    ap.add_argument("--kind", default="gcn")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--cluster", default="1A+2B+1C")
    ap.add_argument("--batches", type=int, nargs="+",
                    default=[1, 2, 4, 8, 16])
    ap.add_argument("--aggregations", nargs="+",
                    default=["segment_sum", "pallas"])
    ap.add_argument("--executors", nargs="+",
                    default=["sim", "single", "cloud"])
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        # Shrink only what the user did not set explicitly.
        if args.batches == ap.get_default("batches"):
            args.batches = [1, 4, 8]
        if args.executors == ap.get_default("executors"):
            args.executors = ["sim"]
        if args.repeats == ap.get_default("repeats"):
            args.repeats = 3
        if args.out == ap.get_default("out"):   # don't dirty the worktree
            import tempfile
            args.out = os.path.join(tempfile.gettempdir(),
                                    "BENCH_serving.smoke.json")

    result = sweep(args)
    rows = result["rows"]

    by_path = {}
    for r in rows:
        if r["batch"] > 1:
            by_path.setdefault(r["aggregation"], []).append(r["speedup"])
    summary = {p: float(np.exp(np.mean(np.log(np.maximum(v, 1e-9)))))
               for p, v in by_path.items()}
    print("geomean batched-over-serial speedup (B>1) per path:",
          {k: round(v, 3) for k, v in summary.items()})

    payload = {
        "benchmark": "serving_latency",
        "backend": __import__("jax").default_backend(),
        "methodology": (
            "steady-state best-of-repeats wall-clock (min: load noise is "
            "additive); off-TPU the Pallas kernels run in interpret "
            "mode, so speedups quantify dispatch amortization (B "
            "executor dispatches -> 1 fused call), not MXU kernel time"),
        "config": {k: v for k, v in vars(args).items() if k != "smoke"},
        "graph": result["graph"],
        "geomean_speedup": summary,
        "parity_ok": result["parity_ok"],
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(rows)} rows)")

    # Acceptance guards. Bit-identity is non-negotiable on every row; the
    # full sweep additionally requires the dispatch-amortization win the
    # PR claims: >= 2x on the kernel path at some batch size >= 8.
    if not result["parity_ok"]:
        print("FAIL: a batched run diverged from its serial loop")
        return 1
    if not args.smoke:
        kernel_wins = [r["speedup"] for r in rows
                       if r["aggregation"] == "pallas" and r["batch"] >= 8]
        if kernel_wins and max(kernel_wins) < 2.0:
            print(f"FAIL: kernel path never reached 2x at B>=8 "
                  f"(best {max(kernel_wins):.2f}x)")
            return 1
    else:
        big = [r["speedup"] for r in rows if r["batch"] >= 8]
        if big and max(big) <= 1.0:
            print("FAIL: batched execution never beat the serial loop")
            return 1
    print("PASS: batched execution bit-identical to the serial loop"
          + ("" if args.smoke else " and >=2x on the kernel path at B>=8"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
