"""SLO benchmark: goodput and deadline-miss curves under overload.

Sweeps arrival rate (as a multiple of the single-request sustainable
rate) x SLO tightness x serving policy for the ``repro.api.Server``
control plane (``repro.api.slo``) against the admit-all fixed-batch
baseline on the *same* mixed-criticality Poisson trace, and writes the
whole trajectory to ``BENCH_slo.json``.

The workload is the paper's smart-IoT serving story under stress: a
minority class of critical traffic (anomaly detection) with a tight
latency budget rides on a majority class of background analytics with a
loose one. Policies:

  admit-all      Server(slo=None): FIFO, fixed max_batch, serves
                 everything however late — the PR 2 baseline.
  slo-fixed      Server(slo=SLOPolicy()): priority-first scheduling,
                 deadline admission with the degradation ladder,
                 rejection of hopeless requests.
  slo-adaptive   slo-fixed + AdaptiveBatchController picking the
                 micro-batch size from the measured latency curve.

Acceptance guard (also run by scripts/ci.sh via --smoke): at >= 2x
overload the control plane must achieve strictly higher goodput AND a
strictly lower high-priority p95 than admit-all.

    PYTHONPATH=src python benchmarks/slo.py            # full sweep
    PYTHONPATH=src python benchmarks/slo.py --smoke    # CI guard
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(REPO, "src", "repro")):
    sys.path.insert(0, os.path.join(REPO, "src"))

HI_PRIORITY = 2          # critical class rank (vs 0 for background)
HI_FRACTION = 0.3        # fraction of traffic in the critical class
LOOSE_FACTOR = 4.0       # background budget = LOOSE_FACTOR x critical budget


def build_plan(args):
    import jax

    from repro.api import Engine
    from repro.gnn import datasets, models

    graph = datasets.load(args.dataset, scale=args.scale, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), args.kind,
                             [graph.feature_dim, args.hidden, 8])
    engine = Engine((params, args.kind), cluster=args.cluster,
                    network=args.network, compressor=args.compressor)
    return engine.compile(graph), graph


def policies(args):
    from repro.api.slo import SLOPolicy
    return {
        "admit-all": {},
        "slo-fixed": {"slo": SLOPolicy()},
        "slo-adaptive": {"slo": SLOPolicy(), "adaptive_batch": True},
    }


def run_policy(plan, trace, *, max_batch: int, server_kw: dict) -> dict:
    from repro.api import Server
    server = plan.server(max_batch=max_batch, max_wait=0.0, **server_kw)
    t0 = time.perf_counter()
    responses = server.replay(list(trace))
    wall = time.perf_counter() - t0
    out = Server.summarize(responses)
    out["wall_s"] = wall
    hi = out.get("priority_classes", {}).get(str(HI_PRIORITY), {})
    out["hi_latency_p95_s"] = hi.get("latency_p95_s")
    out["hi_goodput_rps"] = hi.get("goodput_rps")
    out["hi_deadline_miss_rate"] = hi.get("deadline_miss_rate")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + pass/fail guard (for scripts/ci.sh)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_slo.json"))
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--kind", default="gcn")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--cluster", default="1A+2B+1C")
    ap.add_argument("--network", default="wifi")
    ap.add_argument("--compressor", default="daq")
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--multipliers", type=float, nargs="+",
                    default=[0.5, 1.0, 2.0, 4.0],
                    help="arrival rate as a multiple of 1/service(B=1)")
    ap.add_argument("--tightness", type=float, nargs="+", default=[3.0, 8.0],
                    help="critical-class deadline in multiples of "
                         "service(B=1); background gets LOOSE_FACTOR x that")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        args.scale = 0.05
        args.requests = 48
        args.multipliers = [2.5]
        args.tightness = [3.0]
        if args.out == ap.get_default("out"):   # don't dirty the worktree
            import tempfile
            args.out = os.path.join(tempfile.gettempdir(),
                                    "BENCH_slo.smoke.json")

    from repro.api import slo, traces

    plan, graph = build_plan(args)
    s1 = plan.session().account().total_latency
    print(f"plan: |V|={graph.num_vertices} |E|={graph.num_edges} "
          f"cluster={args.cluster} service(B=1)={s1 * 1e3:.1f}ms "
          f"sustainable={1.0 / s1:.2f} rps requests={args.requests}")

    sweep = []
    print("policy,multiplier,tightness,goodput_rps,throughput_rps,"
          "miss_rate,rejected,degraded,hi_p95_s")
    for tight in args.tightness:
        slo_fn = slo.slo_classes([
            (HI_FRACTION, HI_PRIORITY, tight * s1),
            (1.0 - HI_FRACTION, 0, LOOSE_FACTOR * tight * s1)])
        for mult in args.multipliers:
            rate = mult / s1
            trace = traces.poisson(args.requests, rate, seed=args.seed,
                                   slo_fn=slo_fn)
            for name, kw in policies(args).items():
                row = run_policy(plan, trace, max_batch=args.max_batch,
                                 server_kw=kw)
                row.update(policy=name, multiplier=mult, rate_rps=rate,
                           tightness=tight)
                sweep.append(row)
                p95 = row["hi_latency_p95_s"]
                print(f"{name},{mult},{tight},{row['goodput_rps']:.3f},"
                      f"{row['throughput_rps']:.3f},"
                      f"{row['deadline_miss_rate']:.3f},{row['rejected']},"
                      f"{row['degraded']},"
                      f"{'n/a' if p95 is None else f'{p95:.3f}'}")

    payload = {
        "benchmark": "slo_control_plane",
        "config": {k: v for k, v in vars(args).items() if k != "smoke"},
        "graph": {"vertices": graph.num_vertices, "edges": graph.num_edges},
        "service_b1_s": s1,
        "classes": {"hi": {"priority": HI_PRIORITY, "fraction": HI_FRACTION},
                    "loose_factor": LOOSE_FACTOR},
        "rows": sweep,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(sweep)} rows)")

    # Acceptance guard: under overload (>= 2x sustainable) the control
    # plane must beat admit-all on goodput AND high-priority tail latency.
    by_key = {(r["policy"], r["multiplier"], r["tightness"]): r
              for r in sweep}
    failures = []
    for tight in args.tightness:
        for mult in args.multipliers:
            if mult < 2.0:
                continue
            base = by_key[("admit-all", mult, tight)]
            for name in ("slo-fixed", "slo-adaptive"):
                row = by_key[(name, mult, tight)]
                ok_goodput = row["goodput_rps"] > base["goodput_rps"]
                ok_p95 = (row["hi_latency_p95_s"] is not None
                          and base["hi_latency_p95_s"] is not None
                          and row["hi_latency_p95_s"]
                          < base["hi_latency_p95_s"])
                print(f"guard mult={mult} tight={tight} {name}: "
                      f"goodput {row['goodput_rps']:.3f} vs "
                      f"{base['goodput_rps']:.3f} "
                      f"({'ok' if ok_goodput else 'FAIL'}), "
                      f"hi-p95 {row['hi_latency_p95_s']} vs "
                      f"{base['hi_latency_p95_s']} "
                      f"({'ok' if ok_p95 else 'FAIL'})")
                if not (ok_goodput and ok_p95):
                    failures.append((name, mult, tight))
    if failures:
        print(f"FAIL: control plane lost to admit-all at {failures}")
        return 1
    print("PASS: control plane beats admit-all under overload "
          "(goodput up, high-priority p95 down)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
