"""Aggregate dry-run JSONs into the §Roofline table (EXPERIMENTS.md).

Reads results/dryrun/*.json produced by repro.launch.dryrun and emits a
markdown table with the three roofline terms per (arch x shape x mesh),
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and memory-fit status.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16e9  # v5e


def fmt_s(x):
    if x >= 1.0:
        return f"{x:8.2f}s "
    return f"{x * 1e3:8.2f}ms"


def load_results(path: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def table(rows, mesh: str):
    out = []
    hdr = (f"| arch | shape | mode | compute | memory | collective | "
           f"bound | useful-flop | peak GB/dev | fits |")
    sep = "|" + "---|" * 10
    out.append(hdr)
    out.append(sep)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows = [r for r in rows if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        if not r["ok"]:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mode']} | "
                       f"FAILED: {r['error'][:60]} |||||||")
            continue
        rf = r["roofline"]
        peak = r["memory"].get("peak_bytes_per_device")
        peak_gb = (peak / 1e9) if isinstance(peak, (int, float)) else None
        fits = "yes" if peak_gb is not None and peak_gb <= 16 else \
            (f"NO ({peak_gb:.0f}GB)" if peak_gb else "?")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} | "
            f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | {rf['dominant']} | "
            f"{rf['model_flops_ratio']:.3f} | "
            f"{peak_gb:.1f} | {fits} |" if peak_gb is not None else
            f"| {r['arch']} | {r['shape']} | {r['mode']} | "
            f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | {rf['dominant']} | "
            f"{rf['model_flops_ratio']:.3f} | ? | ? |")
    return "\n".join(out)


def summary(rows):
    ok = [r for r in rows if r["ok"]]
    from collections import Counter
    doms = Counter(r["roofline"]["dominant"] for r in ok
                   if r["mesh"] == "16x16")
    worst = sorted(
        (r for r in ok if r["mesh"] == "16x16"),
        key=lambda r: -(max(r["roofline"]["memory_s"],
                            r["roofline"]["collective_s"])
                        / max(r["roofline"]["compute_s"], 1e-12)))[:5]
    lines = [f"total runs: {len(rows)}, ok: {len(ok)}",
             f"dominant terms (single-pod): {dict(doms)}",
             "worst roofline fraction (compute/max-term):"]
    for r in worst:
        rf = r["roofline"]
        frac = rf["compute_s"] / max(rf["memory_s"], rf["collective_s"],
                                     1e-12)
        lines.append(f"  {r['arch']} x {r['shape']}: {frac:.4f}")
    most_coll = sorted(
        (r for r in ok if r["mesh"] == "16x16"),
        key=lambda r: -r["roofline"]["collective_s"])[:5]
    lines.append("most collective-bound (abs):")
    for r in most_coll:
        lines.append(f"  {r['arch']} x {r['shape']}: "
                     f"{r['roofline']['collective_s']:.2f}s")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load_results(args.path)
    print(summary(rows))
    print()
    print(table(rows, args.mesh))


if __name__ == "__main__":
    main()
