"""Aggregation roofline: segment_sum vs the Pallas block-CSR kernels.

Sweeps the two shard-local aggregation paths (plus the DAQ-fused
``dequant_spmm`` wire variant) over a range of partition counts — i.e.
per-shard sizes — on the exact operands the ``mesh-bsp`` runtime feeds
them: the local [P, F] slot table and the gathered [n*B, F] halo table
built by ``runtime.bsp.build_partitioned``. For every (partition-count,
shard, path) point it reports wall-clock, analytic FLOPs/bytes and the
achieved GFLOP/s / GB/s, and writes the whole sweep to
``BENCH_roofline.json``.

Off-TPU the kernels run in Pallas interpret mode, so absolute kernel
timings there measure the interpreter, not the MXU — the numbers to read
on CPU are the segment-sum baseline, the parity columns and the analytic
roofline terms; on a TPU backend the same script times the real kernels.

    PYTHONPATH=src python benchmarks/roofline.py            # full sweep
    PYTHONPATH=src python benchmarks/roofline.py --smoke    # CI guard

The CI ``--smoke`` mode shrinks the sweep and fails (exit 1) unless every
kernel-path output matches segment_sum within float32 tolerance (and the
DAQ-fused path within quantization tolerance).

The file's previous role — aggregating ``repro.launch.dryrun`` JSONs into
the transformer-substrate roofline table — is kept behind
``--dryrun-path results/dryrun``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(REPO, "src", "repro")):
    sys.path.insert(0, os.path.join(REPO, "src"))

HBM_PER_CHIP = 16e9  # v5e


# ----------------------------------------------------------------------------
# Aggregation-path sweep (the serving hot path)
# ----------------------------------------------------------------------------

def _time_fn(fn, repeats: int) -> float:
    """Median wall-clock of ``fn()`` (jax work block_until_ready'd)."""
    import jax
    times = []
    fn()  # warm up / compile
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(
                x, "block_until_ready") else x, out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _halo_table(g, pg):
    """The gathered [n*B, F] halo table, shared by every shard."""
    import numpy as np
    f = g.feature_dim
    halo = np.zeros((pg.n, pg.boundary_slots, f), np.float32)
    for q in range(pg.n):
        halo[q] = pg.feats[q][pg.boundary_rows[q]] * \
            pg.boundary_mask[q][:, None]
    return halo.reshape(-1, f)


def sweep_partitions(args) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import partition
    from repro.core.compression import _quantize_rows
    from repro.gnn import datasets
    from repro.gnn.layers import EdgeList, aggregate_sum
    from repro.kernels.daq_dequant import dequant_spmm
    from repro.kernels.gather_aggregate import block_spmm
    from repro.runtime import bsp

    g = datasets.load(args.dataset, scale=args.scale, seed=0)
    interpret = jax.default_backend() != "tpu"
    rows = []
    worst = {"pallas": 0.0, "pallas+daq": 0.0}
    for n_parts in args.partitions:
        assign = partition.bgp(g, n_parts, seed=0)
        pg = bsp.build_partitioned(g, assign)
        halo_tab = _halo_table(g, pg)
        for p in range(pg.n):
            h = pg.feats[p]                           # [P, F] local slots
            f = g.feature_dim
            edges_real = int(pg.edge_mask[p].sum())
            # --- segment_sum: gather + scatter-add over the combined table
            h_src = jnp.concatenate([jnp.asarray(h), jnp.asarray(halo_tab)])
            senders = jnp.asarray(pg.senders_halo[p])
            receivers = jnp.asarray(pg.receivers_local[p])
            emask = jnp.asarray(pg.edge_mask[p])
            hj = jnp.asarray(h)

            @jax.jit
            def seg_path(hj=hj, h_src=h_src, senders=senders,
                         receivers=receivers, emask=emask, slots=pg.slots):
                edges = EdgeList(senders, receivers, emask, slots)
                return aggregate_sum(hj, edges, h_src)

            seg = np.asarray(seg_path())
            t_seg = _time_fn(seg_path, args.repeats)

            # --- pallas: local SpMM + halo SpMM over the pre-blocked shards
            lcsr, hcsr = pg.local_csr, pg.halo_csr
            lblk = jnp.asarray(lcsr.blocks[p])
            lcol, lmsk = jnp.asarray(lcsr.cols[p]), jnp.asarray(lcsr.mask[p])
            hblk = jnp.asarray(hcsr.blocks[p])
            hcol, hmsk = jnp.asarray(hcsr.cols[p]), jnp.asarray(hcsr.mask[p])
            loc = jnp.asarray(np.pad(h, ((0, lcsr.src_rows - h.shape[0]),
                                         (0, 0))))
            hal = jnp.asarray(np.pad(
                halo_tab, ((0, hcsr.src_rows - halo_tab.shape[0]), (0, 0))))

            def kernel_path():
                out = block_spmm(lblk, lcol, lmsk, loc, interpret=interpret)
                return out + block_spmm(hblk, hcol, hmsk, hal,
                                        interpret=interpret)

            pal = np.asarray(kernel_path())[:pg.slots]
            t_pal = _time_fn(kernel_path, args.repeats)
            worst["pallas"] = max(worst["pallas"],
                                  float(np.abs(pal - seg).max()))

            # --- pallas + DAQ-fused halo (uint8 wire, dequant in-kernel)
            codes, mins, scales = _quantize_rows(
                np.asarray(hal, np.float64), 8)
            codes = jnp.asarray(codes.astype(np.uint8))
            sc = jnp.asarray(scales.astype(np.float32))
            mn = jnp.asarray(mins.astype(np.float32))

            def fused_path():
                out = block_spmm(lblk, lcol, lmsk, loc, interpret=interpret)
                return out + dequant_spmm(hblk, hcol, hmsk, codes, sc, mn,
                                          interpret=interpret)

            fused = np.asarray(fused_path())[:pg.slots]
            t_fused = _time_fn(fused_path, args.repeats)
            scale_err = float(np.abs(np.asarray(hal)).max()) or 1.0
            worst["pallas+daq"] = max(
                worst["pallas+daq"],
                float(np.abs(fused - seg).max()) / scale_err)

            # --- analytic roofline terms (per shard-local aggregation)
            flops = 2.0 * edges_real * f
            seg_bytes = (edges_real * f * 4        # gathered messages
                         + pg.slots * f * 4 * 2)   # acc read+write
            n_tiles = int(lcsr.mask[p].sum() + hcsr.mask[p].sum())
            blk = lblk.shape[-1]
            pal_bytes = (n_tiles * blk * blk * 4        # adjacency tiles
                         + n_tiles * blk * f * 4        # source panels
                         + pg.slots * f * 4)            # output
            fused_bytes = (n_tiles * blk * blk * 4
                           + int(lcsr.mask[p].sum()) * blk * f * 4
                           + int(hcsr.mask[p].sum()) * blk * (f + 8)
                           + pg.slots * f * 4)
            for path, t, nbytes in (("segment_sum", t_seg, seg_bytes),
                                    ("pallas", t_pal, pal_bytes),
                                    ("pallas+daq", t_fused, fused_bytes)):
                rows.append({
                    "partitions": n_parts, "part": p,
                    "vertices": int(pg.vertex_mask[p].sum()),
                    "edges": edges_real, "feature_dim": f,
                    "halo_rows": int(pg.boundary_mask.sum()),
                    "path": path, "time_s": t,
                    "flops": flops, "bytes": nbytes,
                    "gflops": flops / t / 1e9,
                    "gbs": nbytes / t / 1e9,
                    "speedup_vs_segment_sum": t_seg / t,
                })
    return {"rows": rows, "max_abs_err": worst,
            "graph": {"vertices": g.num_vertices, "edges": g.num_edges,
                      "feature_dim": g.feature_dim}}


def print_rows(rows) -> None:
    hdr = (f"{'n':>3} {'part':>4} {'|V|':>6} {'|E|':>7} {'path':<12} "
           f"{'time':>10} {'GFLOP/s':>9} {'GB/s':>8} {'vs seg':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['partitions']:>3} {r['part']:>4} {r['vertices']:>6} "
              f"{r['edges']:>7} {r['path']:<12} {r['time_s'] * 1e3:>8.3f}ms "
              f"{r['gflops']:>9.3f} {r['gbs']:>8.3f} "
              f"{r['speedup_vs_segment_sum']:>6.2f}x")


def main_sweep(args) -> int:
    import numpy as np

    result = sweep_partitions(args)
    rows = result["rows"]
    print_rows(rows)
    by_path = {}
    for r in rows:
        by_path.setdefault(r["path"], []).append(r["speedup_vs_segment_sum"])
    summary = {p: float(np.exp(np.mean(np.log(v))))
               for p, v in by_path.items()}
    print("geomean speedup vs segment_sum per path:",
          {k: round(v, 3) for k, v in summary.items()})
    print("max parity error vs segment_sum:", result["max_abs_err"])

    payload = {
        "benchmark": "aggregation_roofline",
        "backend": __import__("jax").default_backend(),
        "config": {k: v for k, v in vars(args).items()
                   if k not in ("smoke", "dryrun_path", "mesh", "out")},
        "graph": result["graph"],
        "geomean_speedup_vs_segment_sum": summary,
        "max_abs_err": result["max_abs_err"],
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(rows)} rows)")

    # Acceptance guard: the kernel paths must agree with segment_sum —
    # exactly (f32) for the float path, within 8-bit quantization error
    # for the DAQ-fused wire.
    err = result["max_abs_err"]
    if err["pallas"] > 1e-3:
        print(f"FAIL: pallas path diverges from segment_sum ({err})")
        return 1
    if err["pallas+daq"] > 5e-2:
        print(f"FAIL: DAQ-fused path outside quantization tolerance ({err})")
        return 1
    print("PASS: kernel aggregation matches segment_sum on every shard")
    return 0


# ----------------------------------------------------------------------------
# Legacy mode: aggregate repro.launch.dryrun JSONs (transformer substrate)
# ----------------------------------------------------------------------------

def fmt_s(x):
    if x >= 1.0:
        return f"{x:8.2f}s "
    return f"{x * 1e3:8.2f}ms"


def load_results(path: str):
    import glob
    rows = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def table(rows, mesh: str):
    out = []
    hdr = (f"| arch | shape | mode | compute | memory | collective | "
           f"bound | useful-flop | peak GB/dev | fits |")
    sep = "|" + "---|" * 10
    out.append(hdr)
    out.append(sep)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows = [r for r in rows if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        if not r["ok"]:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mode']} | "
                       f"FAILED: {r['error'][:60]} |||||||")
            continue
        rf = r["roofline"]
        peak = r["memory"].get("peak_bytes_per_device")
        peak_gb = (peak / 1e9) if isinstance(peak, (int, float)) else None
        fits = "yes" if peak_gb is not None and peak_gb <= 16 else \
            (f"NO ({peak_gb:.0f}GB)" if peak_gb else "?")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} | "
            f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | {rf['dominant']} | "
            f"{rf['model_flops_ratio']:.3f} | "
            f"{peak_gb:.1f} | {fits} |" if peak_gb is not None else
            f"| {r['arch']} | {r['shape']} | {r['mode']} | "
            f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | {rf['dominant']} | "
            f"{rf['model_flops_ratio']:.3f} | ? | ? |")
    return "\n".join(out)


def summary(rows):
    ok = [r for r in rows if r["ok"]]
    from collections import Counter
    doms = Counter(r["roofline"]["dominant"] for r in ok
                   if r["mesh"] == "16x16")
    worst = sorted(
        (r for r in ok if r["mesh"] == "16x16"),
        key=lambda r: -(max(r["roofline"]["memory_s"],
                            r["roofline"]["collective_s"])
                        / max(r["roofline"]["compute_s"], 1e-12)))[:5]
    lines = [f"total runs: {len(rows)}, ok: {len(ok)}",
             f"dominant terms (single-pod): {dict(doms)}",
             "worst roofline fraction (compute/max-term):"]
    for r in worst:
        rf = r["roofline"]
        frac = rf["compute_s"] / max(rf["memory_s"], rf["collective_s"],
                                     1e-12)
        lines.append(f"  {r['arch']} x {r['shape']}: {frac:.4f}")
    most_coll = sorted(
        (r for r in ok if r["mesh"] == "16x16"),
        key=lambda r: -r["roofline"]["collective_s"])[:5]
    lines.append("most collective-bound (abs):")
    for r in most_coll:
        lines.append(f"  {r['arch']} x {r['shape']}: "
                     f"{r['roofline']['collective_s']:.2f}s")
    return "\n".join(lines)


def main_dryrun_table(args) -> int:
    rows = load_results(args.dryrun_path)
    print(summary(rows))
    print()
    print(table(rows, args.mesh))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + pass/fail parity guard (scripts/ci.sh)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_roofline.json"))
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--partitions", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--dryrun-path", default=None,
                    help="legacy mode: aggregate repro.launch.dryrun JSONs "
                         "from this directory into the §Roofline table")
    ap.add_argument("--mesh", default="16x16",
                    help="(legacy mode) mesh filter for the dryrun table")
    args = ap.parse_args(argv)

    if args.dryrun_path:
        return main_dryrun_table(args)

    if args.smoke:
        # Shrink only what the user did not set explicitly.
        if args.scale == ap.get_default("scale"):
            args.scale = 0.05
        if args.partitions == ap.get_default("partitions"):
            args.partitions = [2, 4]
        if args.repeats == ap.get_default("repeats"):
            args.repeats = 2
        if args.out == ap.get_default("out"):   # don't dirty the worktree
            import tempfile
            args.out = os.path.join(tempfile.gettempdir(),
                                    "BENCH_roofline.smoke.json")
    return main_sweep(args)


if __name__ == "__main__":
    raise SystemExit(main())
