"""One benchmark per paper table/figure (Fograph, CS.DC'23).

Each function reproduces one artifact and returns rows of
(name, value, paper_value_or_note). The runner prints CSV.

Scale: ``FULL=1`` env runs paper-size graphs; default uses scale=0.15
graphs so the whole suite finishes in CI time. Ratios (the paper's claims)
are scale-stable because both sides of each ratio shrink together.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import compression, placement, scheduler, simulation
from repro.gnn import datasets, models
from repro.gnn.graph import degree_cdf
from repro.gnn.layers import EdgeList

# Simulation-only figures run the paper-size graphs (cheap: no training);
# training-heavy benchmarks (Table IV) reduce the graph unless FULL=1.
SIM_SCALE = 0.15 if os.environ.get("QUICK") else 1.0
SCALE = 1.0 if os.environ.get("FULL") else 0.15
SEED = 0
NETWORKS = ("4g", "5g", "wifi")
GNNS = ("gcn", "gat", "sage")


def _cluster(g, spec="1A+4B+1C", net="wifi", k_layers=2):
    return simulation.make_cluster(spec, net, g, k_layers=k_layers)


def _placements(g, cluster, seed=SEED):
    fogs = cluster.fog_specs(seed=seed)
    pl_iep = placement.iep_place(g, fogs, strategy="iep", seed=seed,
                                 sync_cost=cluster.sync_cost)
    pl_rand = placement.iep_place(g, fogs, strategy="random", seed=seed,
                                  sync_cost=cluster.sync_cost)
    return fogs, pl_iep, pl_rand


# ---------------------------------------------------------------- Fig. 3/4

def fig3_motivation():
    """Cloud vs single-fog vs multi-fog latency + stage breakdown."""
    g = datasets.load("siot", scale=SIM_SCALE, seed=SEED)
    rows = []
    paper_speedup = {"4g": 1.65, "5g": 1.73, "wifi": 1.40}
    for net in NETWORKS:
        cluster = _cluster(g, net=net)
        fogs, pl_iep, pl_rand = _placements(g, cluster)
        cloud = simulation.simulate_cloud(cluster)
        single = simulation.simulate_single_fog(cluster)
        multi = simulation.simulate_multi_fog(cluster, pl_rand)
        rows.append((f"fig3/{net}/cloud_latency_s", cloud.total_latency, ""))
        rows.append((f"fig3/{net}/single_fog_latency_s",
                     single.total_latency, ""))
        rows.append((f"fig3/{net}/multi_fog_latency_s",
                     multi.total_latency, ""))
        rows.append((f"fig3/{net}/single_fog_speedup",
                     cloud.total_latency / single.total_latency,
                     f"paper {paper_speedup[net]}"))
        rows.append((f"fig3/{net}/collect_reduction",
                     1 - single.collect[0] / cloud.collect[0],
                     {"4g": "paper 0.64", "5g": "paper 0.67",
                      "wifi": "paper 0.61"}[net]))
        rows.append((f"fig3/{net}/cloud_exec_fraction",
                     cloud.breakdown()["execute"] / cloud.total_latency,
                     "paper <0.02"))
    # Fig. 4: random placement balances vertices but not load
    cluster = _cluster(g, net="wifi")
    fogs, pl_iep, pl_rand = _placements(g, cluster)
    t = simulation.measured_exec_times(cluster, pl_rand)
    sizes = np.bincount(pl_rand.assignment, minlength=len(fogs))
    rows.append(("fig4/vertex_count_cv", sizes.std() / sizes.mean(),
                 "~0 (balanced)"))
    rows.append(("fig4/exec_time_cv", t.std() / t.mean(),
                 ">> vertex cv (imbalance)"))
    return rows


# ------------------------------------------------------------------ Fig. 8

def fig8_iep_vs_strawman():
    """IEP vs METIS+Random vs METIS+Greedy in 3 environments."""
    g = datasets.load("siot", scale=SIM_SCALE, seed=SEED)
    envs = {"E1": ("1A+4B+1C", "4g"), "E2": ("1A+4B+1C", "5g"),
            "E3": ("1A+2B+1C", "wifi")}
    rows = []
    for env, (spec, net) in envs.items():
        cluster = _cluster(g, spec=spec, net=net)
        fogs = cluster.fog_specs(seed=SEED)
        res = {}
        for strat in ("iep", "greedy", "random"):
            pl = placement.iep_place(g, fogs, strategy=strat, seed=SEED,
                                     sync_cost=cluster.sync_cost)
            res[strat] = simulation.simulate_multi_fog(cluster,
                                                       pl).total_latency
        rows.append((f"fig8/{env}/iep_latency_s", res["iep"], ""))
        rows.append((f"fig8/{env}/greedy_latency_s", res["greedy"], ""))
        rows.append((f"fig8/{env}/random_latency_s", res["random"], ""))
        rows.append((f"fig8/{env}/iep_vs_greedy_reduction",
                     1 - res["iep"] / res["greedy"],
                     "paper avg 0.109-0.195"))
    return rows


# ------------------------------------------------------------- Fig. 11/12

def fig11_12_latency_throughput():
    """Latency + throughput grid: models x datasets x networks."""
    rows = []
    for ds in ("siot", "yelp"):
        g = datasets.load(ds, scale=SIM_SCALE, seed=SEED)
        for net in NETWORKS:
            cluster = _cluster(g, net=net)
            fogs, pl_iep, pl_rand = _placements(g, cluster)
            cloud = simulation.simulate_cloud(cluster)
            fog = simulation.simulate_multi_fog(cluster, pl_rand)
            fograph = simulation.simulate_multi_fog(cluster, pl_iep,
                                                    compress="daq")
            rows.append((f"fig11/{ds}-{net}/cloud_s", cloud.total_latency,
                         ""))
            rows.append((f"fig11/{ds}-{net}/fog_s", fog.total_latency, ""))
            rows.append((f"fig11/{ds}-{net}/fograph_s",
                         fograph.total_latency, "paper <1s"))
            rows.append((f"fig11/{ds}-{net}/speedup_vs_cloud",
                         cloud.total_latency / fograph.total_latency,
                         "paper <=5.39"))
            rows.append((f"fig11/{ds}-{net}/latency_reduction_vs_fog",
                         1 - fograph.total_latency / fog.total_latency,
                         "paper <=0.637"))
            rows.append((f"fig12/{ds}-{net}/throughput_gain_vs_cloud",
                         fograph.throughput / cloud.throughput,
                         "paper <=6.84"))
            rows.append((f"fig12/{ds}-{net}/throughput_gain_vs_fog",
                         fograph.throughput / fog.throughput,
                         "paper <=2.31"))
    return rows


# ---------------------------------------------------------------- Table IV

def table4_accuracy():
    """Inference accuracy: full precision vs Fograph DAQ."""
    rows = []
    for ds in ("siot", "yelp"):
        g = datasets.load(ds, scale=SCALE, seed=SEED)
        edges = EdgeList.from_graph(g)
        packed = compression.daq_pack(g.features.astype(np.float64),
                                      g.degrees)
        rec = compression.daq_unpack(packed).astype(np.float32)
        for kind in GNNS:
            params, _ = models.train_node_classifier(
                jax.random.PRNGKey(SEED), kind, g, steps=80)
            ref = models.gnn_apply(params, kind, g.features, edges)
            out = models.gnn_apply(params, kind, rec, edges)
            a0 = float(models.accuracy(ref, g.labels))
            a1 = float(models.accuracy(out, g.labels))
            rows.append((f"tab4/{ds}/{kind}/full_acc", a0, ""))
            rows.append((f"tab4/{ds}/{kind}/fograph_acc", a1,
                         "paper drop <0.001"))
    return rows


# ------------------------------------------------- Table V + Fig. 13 (case)

def table5_case_study():
    """Traffic flow forecasting (ASTGCN-lite on PeMS): errors + serving."""
    tg = datasets.load_pems_window(scale=1.0, seed=SEED)
    g = tg.graph
    params, (mu, sd), _ = models.train_astgcn(jax.random.PRNGKey(SEED), tg,
                                              steps=300)
    edges = EdgeList.from_graph(g)
    rows = []

    def forecast(features_t):
        import dataclasses as dc
        hist = features_t
        pred = models.astgcn_apply(params, hist, edges)
        return np.asarray(pred) * sd + mu

    full = forecast(tg.history)
    packed = compression.daq_pack(
        tg.history.transpose(1, 0, 2).reshape(g.num_vertices, -1).astype(
            np.float64), g.degrees)
    rec = compression.daq_unpack(packed).astype(np.float32).reshape(
        g.num_vertices, tg.history.shape[0], -1).transpose(1, 0, 2)
    daq = forecast(rec)
    uni = compression.uniform_pack(
        tg.history.transpose(1, 0, 2).reshape(g.num_vertices, -1).astype(
            np.float64), 8)
    rec8 = compression.daq_unpack(uni).astype(np.float32).reshape(
        g.num_vertices, tg.history.shape[0], -1).transpose(1, 0, 2)
    uni8 = forecast(rec8)
    for name, pred in (("full", full), ("fograph", daq), ("uni8", uni8)):
        err = models.forecast_errors(pred[:3], tg.target[:3])  # 15-min
        for k, v in err.items():
            rows.append((f"tab5/15min/{name}/{k}", v,
                         "paper: fograph ~= full; uni8 worse"))
    # Fig. 13: serving latency with the 4-node cluster. The served payload
    # is the full 12-step history window (36 values/sensor) and the ASTGCN
    # execution is ~4 GCN-equivalents (temporal+spatial attention + conv).
    import dataclasses as _dc
    g_srv = _dc.replace(
        g, features=tg.history.transpose(1, 0, 2).reshape(
            g.num_vertices, -1).astype(np.float32))
    cluster = simulation.make_cluster("1A+2B+1C", "4g", g_srv,
                                      hidden=256, k_layers=4)
    fogs, pl_iep, pl_rand = _placements(g_srv, cluster)
    cloud = simulation.simulate_cloud(cluster)
    fograph = simulation.simulate_multi_fog(cluster, pl_iep, compress="daq")
    rows.append(("fig13/speedup_vs_cloud",
                 cloud.total_latency / fograph.total_latency,
                 "paper <=2.79"))
    rows.append(("fig13/fograph_s", fograph.total_latency, ""))
    rows.append(("fig13/cloud_s", cloud.total_latency, ""))
    # load distribution: most powerful node gets most vertices (paper 13b)
    sizes = np.bincount(pl_iep.assignment, minlength=4)
    caps = [n.capability for n in cluster.nodes]
    rows.append(("fig13/most_powerful_has_most_vertices",
                 float(sizes[int(np.argmax(caps))] == sizes.max()),
                 "paper: type-C most vertices"))
    t = simulation.measured_exec_times(cluster, pl_iep)
    rows.append(("fig13/exec_time_cv_after_iep", t.std() / t.mean(),
                 "low (balanced)"))
    return rows


# ---------------------------------------------------------------- Fig. 15

def fig15_ablation():
    """Fograph vs w/o IEP vs w/o CO vs straw-man fog."""
    g = datasets.load("siot", scale=SIM_SCALE, seed=SEED)
    cluster = _cluster(g, spec="1A+2B+1C", net="wifi")
    fogs, pl_iep, pl_rand = _placements(g, cluster)
    full = simulation.simulate_multi_fog(cluster, pl_iep, compress="daq")
    no_iep = simulation.simulate_multi_fog(cluster, pl_rand, compress="daq")
    no_co = simulation.simulate_multi_fog(cluster, pl_iep, compress=None)
    fog = simulation.simulate_multi_fog(cluster, pl_rand, compress=None)
    rows = [("fig15/fograph_s", full.total_latency, "")]
    for name, r in (("wo_iep", no_iep), ("wo_co", no_co), ("fog", fog)):
        rows.append((f"fig15/{name}_s", r.total_latency, ""))
        rows.append((f"fig15/{name}_norm", r.total_latency
                     / full.total_latency, ">1"))
    # orthogonality: both ablations hurt, combination best
    rows.append(("fig15/both_modules_help",
                 float(full.total_latency <= min(no_iep.total_latency,
                                                 no_co.total_latency)), "1"))
    return rows


# ---------------------------------------------------------------- Fig. 16

def fig16_dynamics():
    """Load-trace adaptation: scheduler vs no-scheduler latency."""
    g = datasets.load("siot", scale=SIM_SCALE, seed=SEED)
    cluster = _cluster(g, spec="1A+2B+1C", net="wifi")
    fogs = cluster.fog_specs(seed=SEED)
    pl0 = placement.iep_place(g, fogs, seed=SEED,
                              sync_cost=cluster.sync_cost)
    # Alibaba-style CPU trace: node 0 ramps up then down.
    tsteps = 40
    trace = np.zeros((tsteps, len(cluster.nodes)))
    trace[:, 0] = np.clip(np.sin(np.linspace(0, np.pi, tsteps)) * 3.0, 0, 3)
    lat_sched, lat_fixed = [], []
    st = scheduler.SchedulerState(placement=pl0)
    for ts in range(tsteps):
        simulation.apply_load_trace(cluster, trace[ts])
        lat_fixed.append(simulation.simulate_multi_fog(
            cluster, pl0, compress="daq").total_latency)
        t_real = simulation.measured_exec_times(cluster, st.placement)
        st = scheduler.schedule_step(g, st, fogs, t_real, lam=1.25,
                                     sync_cost=cluster.sync_cost)
        lat_sched.append(simulation.simulate_multi_fog(
            cluster, st.placement, compress="daq").total_latency)
    lat_sched, lat_fixed = np.array(lat_sched), np.array(lat_fixed)
    peak = trace[:, 0] > 2.0
    rows = [
        ("fig16/peak_latency_no_scheduler_s", float(lat_fixed[peak].max()),
         ""),
        ("fig16/peak_latency_with_scheduler_s", float(lat_sched[peak].max()),
         "lower"),
        ("fig16/peak_reduction", 1 - float(lat_sched[peak].max()
                                           / lat_fixed[peak].max()),
         "paper <=0.188"),
        ("fig16/migrations", float(st.migrations), ">0"),
    ]
    return rows


# ---------------------------------------------------------------- Fig. 17

def fig17_scalability():
    """RMAT series: latency vs #fogs."""
    rows = []
    series = ["rmat-20k", "rmat-60k", "rmat-100k"] if os.environ.get("FULL") \
        else ["rmat-20k", "rmat-40k"]
    scale = 1.0 if os.environ.get("FULL") else 0.4
    for ds in series:
        g = datasets.load(ds, scale=scale, seed=SEED)
        prev = None
        for n in (1, 2, 4, 6):
            cluster = _cluster(g, spec=f"{n}B", net="wifi")
            if n == 1:
                r = simulation.simulate_single_fog(cluster, compress="daq")
            else:
                fogs = cluster.fog_specs(seed=SEED)
                pl = placement.iep_place(g, fogs, seed=SEED,
                                         sync_cost=cluster.sync_cost)
                r = simulation.simulate_multi_fog(cluster, pl,
                                                  compress="daq")
            rows.append((f"fig17/{ds}/{n}fogs_s", r.total_latency, ""))
            prev = r.total_latency
    return rows


# ---------------------------------------------------------------- Fig. 18

def fig18_accelerator():
    """GPU enhancement analogue: accelerator-equipped type-B fogs."""
    g = datasets.load("rmat-20k", scale=0.3 if not os.environ.get("FULL")
                      else 1.0, seed=SEED)
    rows = []
    gpu_boost = 12.0  # GTX1050 vs i7 on GNN workloads
    gpu_mem_vertices = g.num_vertices // 2  # OOM threshold (paper: 1 fog OOMs)
    for n in (1, 2, 4, 6):
        cluster = _cluster(g, spec=f"{n}B", net="wifi")
        fogs = cluster.fog_specs(seed=SEED)
        if n == 1:
            cpu = simulation.simulate_single_fog(cluster, compress="daq")
            rows.append((f"fig18/{n}fog/cpu_s", cpu.total_latency, ""))
            rows.append((f"fig18/{n}fog/gpu_s", float("nan"),
                         "paper: OOM"))
            continue
        pl = placement.iep_place(g, fogs, seed=SEED,
                                 sync_cost=cluster.sync_cost)
        cpu = simulation.simulate_multi_fog(cluster, pl, compress="daq")
        for node in cluster.nodes:
            node.capability *= gpu_boost
        # re-profile with accelerators
        fogs_gpu = cluster.fog_specs(seed=SEED)
        pl_gpu = placement.iep_place(g, fogs_gpu, seed=SEED,
                                     sync_cost=cluster.sync_cost)
        gpu = simulation.simulate_multi_fog(cluster, pl_gpu, compress="daq")
        max_part = np.bincount(pl_gpu.assignment).max()
        oom = max_part > gpu_mem_vertices
        rows.append((f"fig18/{n}fog/cpu_s", cpu.total_latency, ""))
        rows.append((f"fig18/{n}fog/gpu_s",
                     float("nan") if oom else gpu.total_latency,
                     "OOM" if oom else "faster than cpu"))
    return rows


# ------------------------------------------------------------------- Thm 2

def thm2_compression():
    """Closed-form vs measured compression ratio on every dataset."""
    rows = []
    for ds in ("siot", "yelp", "rmat-20k"):
        g = datasets.load(ds, scale=SCALE, seed=SEED)
        th = compression.quantile_thresholds(g.degrees)
        packed = compression.daq_pack(g.features.astype(np.float64),
                                      g.degrees, thresholds=th,
                                      lossless=True)
        ratio = compression.theorem2_ratio(degree_cdf(g), th)
        rows.append((f"thm2/{ds}/closed_form", ratio, ""))
        rows.append((f"thm2/{ds}/measured", packed.measured_ratio,
                     "== closed form"))
        rows.append((f"thm2/{ds}/wire_ratio",
                     packed.nbytes(True) / (packed.raw_bits // 8),
                     "with lossless stage"))
    return rows


ALL = [fig3_motivation, fig8_iep_vs_strawman, fig11_12_latency_throughput,
       table4_accuracy, table5_case_study, fig15_ablation, fig16_dynamics,
       fig17_scalability, fig18_accelerator, thm2_compression]


# ------------------------------------------------- beyond-paper: SSVI items

def daq_frontier():
    """The paper leaves '<D1,D2,D3> and <q0..q3> exploration' as future
    work (SSIII-D). We sweep bit tuples over the accuracy-vs-wire-bytes
    frontier on SIoT: the default <64,32,16,8> is NOT on the frontier —
    <32,16,8,8> halves the wire bytes at zero accuracy cost."""
    rows = []
    for ds in ("siot", "yelp"):
        g = datasets.load(ds, scale=SCALE, seed=SEED)
        edges = EdgeList.from_graph(g)
        params, _ = models.train_node_classifier(jax.random.PRNGKey(SEED),
                                                 "gcn", g, steps=80)
        ref = models.gnn_apply(params, "gcn", g.features, edges)
        acc0 = float(models.accuracy(ref, g.labels))
        rows.append((f"daq_frontier/{ds}/full_precision_acc", acc0, ""))
        for bits in [(64, 32, 16, 8), (32, 16, 8, 8), (16, 16, 8, 8),
                     (16, 8, 8, 8), (8, 8, 8, 4), (8, 4, 4, 4)]:
            packed = compression.daq_pack(g.features.astype(np.float64),
                                          g.degrees, bits=bits)
            rec = compression.daq_unpack(packed).astype(np.float32)
            out = models.gnn_apply(params, "gcn", rec, edges)
            acc = float(models.accuracy(out, g.labels))
            tag = "x".join(str(b) for b in bits)
            rows.append((f"daq_frontier/{ds}/{tag}/wire_bytes",
                         float(packed.nbytes(True)), ""))
            rows.append((f"daq_frontier/{ds}/{tag}/acc_drop", acc0 - acc,
                         ""))
    return rows


ALL.append(daq_frontier)
