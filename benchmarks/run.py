"""Benchmark runner: one function per paper table/figure.

Prints ``name,value,note`` CSV rows plus per-benchmark wall time. Kernel
micro-benchmarks report us_per_call. Set FULL=1 for paper-scale graphs.

The roofline/dry-run analysis lives in ``benchmarks.roofline`` (reads
results/dryrun produced by ``repro.launch.dryrun``) because it needs a
512-device process.
"""
from __future__ import annotations

import time

import numpy as np


def kernel_microbench():
    """us/call for the Pallas kernels (interpret mode on CPU; on-TPU these
    compile to MXU kernels — numbers here track relative block shapes)."""
    from repro.gnn import datasets
    from repro.kernels import ops
    from repro.kernels.ops import dequantize_features

    g = datasets.load("yelp", scale=0.1, seed=0)
    rng = np.random.default_rng(0)
    h = rng.normal(size=(g.num_vertices, 128)).astype(np.float32)
    bc = ops.BlockCsr(g)
    bc.aggregate(h)  # compile
    reps = 3
    t0 = time.perf_counter()
    for _ in range(reps):
        bc.aggregate(h)
    agg_us = (time.perf_counter() - t0) / reps * 1e6
    codes = rng.integers(0, 255, (g.num_vertices, 128)).astype(np.uint8)
    sc = rng.uniform(0.01, 1, g.num_vertices).astype(np.float32)
    mn = rng.normal(size=g.num_vertices).astype(np.float32)
    dequantize_features(codes, sc, mn)
    t0 = time.perf_counter()
    for _ in range(reps):
        dequantize_features(codes, sc, mn)
    dq_us = (time.perf_counter() - t0) / reps * 1e6
    return [("kernel/block_spmm_us_per_call", agg_us, "interpret mode"),
            ("kernel/dequant_us_per_call", dq_us, "interpret mode")]


def main() -> None:
    from benchmarks import paper_figures

    total_t0 = time.time()
    print("name,value,note")
    for fn in paper_figures.ALL + [kernel_microbench]:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001 — keep the suite running
            print(f"{fn.__name__}/ERROR,nan,{type(e).__name__}: {e}")
            continue
        for name, value, note in rows:
            if isinstance(value, float):
                print(f"{name},{value:.6g},{note}")
            else:
                print(f"{name},{value},{note}")
        print(f"# {fn.__name__} took {time.time() - t0:.1f}s")
    print(f"# total {time.time() - total_t0:.1f}s")


if __name__ == "__main__":
    main()
