"""Dynamic-graph update benchmark: incremental repair vs full recompile.

Streams random ``GraphDelta``s (vertex churn, edge churn, feature upserts)
into a compiled plan and times ``Engine.apply_delta`` — localized partition
repair + dirty-shard rebuild — against the full ``Engine.compile`` pipeline
on the same mutated graph, sweeping delta size x update count for two
pipeline shapes:

  * ``segment_sum``  — no pre-blocked shards; the repair win is skipped
    profiling/BGP/IEP.
  * ``pallas``       — block-CSR shards in the plan; the repair
    additionally reuses every clean shard's ELL-block-CSR operands.

Every row also checks *parity*: a query on the incrementally updated plan
must be bit-identical to a query on the freshly compiled plan (both via the
single-program executor, whose numerics are partition-independent).

    PYTHONPATH=src python benchmarks/updates.py            # full sweep
    PYTHONPATH=src python benchmarks/updates.py --smoke    # CI guard
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(REPO, "src", "repro")):
    sys.path.insert(0, os.path.join(REPO, "src"))


def random_delta(graph, frac: float, rng: np.random.Generator,
                 assignment=None):
    """A mixed delta touching ~``frac`` of the graph's vertices.

    With ``assignment`` the delta is *localized*: every touched vertex
    lives in one randomly chosen partition — the geo-correlated churn of
    co-located IoT sensors, and the case where dirty-shard tracking keeps
    most block-CSR operands clean.
    """
    from repro.api import GraphDelta
    v = graph.num_vertices
    if assignment is None:
        pool = np.arange(v)
    else:
        p = int(rng.integers(int(assignment.max()) + 1))
        pool = np.flatnonzero(assignment == p)
        if pool.size < 4:
            pool = np.arange(v)
    k_add = max(1, int(frac * v))
    k_rem = min(max(1, int(frac * v * 0.5)), max(1, pool.size // 4))
    feats = rng.normal(size=(k_add, graph.feature_dim)).astype(np.float32)
    fanout = rng.integers(2, 5, size=k_add)
    senders = np.repeat(v + np.arange(k_add), fanout)
    targets = rng.choice(pool, size=int(fanout.sum()))
    removed = rng.choice(pool, size=k_rem, replace=False)
    in_pool = np.zeros(v, bool)
    in_pool[pool] = True
    cand = np.flatnonzero(in_pool[graph.receivers])
    eidx = rng.choice(cand, size=min(len(cand), max(
        1, int(frac * graph.num_edges * 0.1))), replace=False)
    rem_edges = np.stack([graph.senders[eidx], graph.receivers[eidx]],
                         axis=1)
    upd = np.setdiff1d(rng.choice(pool, size=min(k_add, pool.size),
                                  replace=False), removed)
    return GraphDelta(
        add_features=feats,
        add_edges=np.stack([senders, targets], axis=1),
        remove_vertices=removed, remove_edges=rem_edges,
        feature_ids=upd,
        feature_values=rng.normal(size=(len(upd), graph.feature_dim)))


def build_engine(args, aggregation: str):
    import jax

    from repro.api import Engine
    from repro.gnn import datasets, models

    graph = datasets.load(args.dataset, scale=args.scale, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), args.kind,
                             [graph.feature_dim, args.hidden, 8])
    # The pallas shape compiles block shards into the plan without needing
    # mesh devices (parity queries run through the single-program backend).
    executor = "mesh-bsp" if aggregation == "pallas" else "sim"
    engine = Engine((params, args.kind), cluster=args.cluster,
                    network=args.network, compressor=args.compressor,
                    executor=executor, aggregation=aggregation)
    return engine, graph


def parity_query(plan):
    """Partition-independent numerics: single-program segment_sum query."""
    sess = plan.session(executor="sim", aggregation="segment_sum")
    return sess.query().embeddings


def buffers_match(plan) -> bool:
    """The real parity guard: the incrementally rebuilt partition buffers
    (dirty-shard reuse included) must equal a from-scratch
    ``build_partitioned`` of the mutated graph at the repaired assignment,
    bit for bit.  Embedding parity alone cannot catch stale shard reuse —
    the single-program query ignores the partition layout entirely.
    """
    from repro.runtime import bsp
    pg = plan.partitioned
    ref = bsp.build_partitioned(plan.graph, plan.placement.assignment,
                                n=plan.num_fogs,
                                build_blocks=pg.local_csr is not None)
    for name in ("feats", "vertex_mask", "senders_global", "senders_halo",
                 "receivers_local", "edge_mask", "boundary_rows",
                 "boundary_mask", "part_of", "slot_of"):
        if not np.array_equal(getattr(ref, name), getattr(pg, name)):
            return False
    for attr in ("local_csr", "halo_csr"):
        a, b = getattr(ref, attr), getattr(pg, attr)
        if (a is None) != (b is None):
            return False
        if a is not None:
            for f in ("blocks", "cols", "mask"):
                if not np.array_equal(getattr(a, f), getattr(b, f)):
                    return False
            if (a.src_rows, a.out_rows) != (b.src_rows, b.out_rows):
                return False
    return True


def run_config(args, aggregation: str, frac: float, n_updates: int,
               seed: int, locality: str = "global") -> dict:
    engine, graph = build_engine(args, aggregation)
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    plan = engine.compile(graph)
    t_compile0 = time.perf_counter() - t0

    t_inc = t_full = 0.0
    modes = []
    shards_rebuilt = 0
    local_rebuilt = halo_rebuilt = 0
    for _ in range(n_updates):
        delta = random_delta(
            plan.graph, frac, rng,
            assignment=plan.placement.assignment
            if locality == "local" else None)
        t0 = time.perf_counter()
        plan_next = engine.apply_delta(plan, delta)
        t_inc += time.perf_counter() - t0
        t0 = time.perf_counter()
        plan_full = engine.compile(plan_next.graph)
        t_full += time.perf_counter() - t0
        modes.append(plan_next.update_report.mode)
        shards_rebuilt += plan_next.update_report.shards_rebuilt
        local_rebuilt += len(plan_next.update_report.dirty_local)
        halo_rebuilt += len(plan_next.update_report.dirty_halo)
        plan = plan_next

    emb_inc = parity_query(plan)
    emb_full = parity_query(plan_full)
    # Embeddings are partition-independent on the single-program path, so
    # the buffer comparison (after the whole chain) is the guard that can
    # actually trip on a repair bug.
    parity = bool(np.array_equal(emb_inc, emb_full)) and buffers_match(plan)
    return {
        "aggregation": aggregation, "locality": locality,
        "delta_frac": frac,
        "n_updates": n_updates, "t_compile_s": t_compile0,
        "t_incremental_s": t_inc, "t_full_recompile_s": t_full,
        "speedup": t_full / max(t_inc, 1e-12),
        "modes": modes, "shards_rebuilt": shards_rebuilt,
        "local_shards_rebuilt": local_rebuilt,
        "halo_shards_rebuilt": halo_rebuilt,
        "num_partitions": plan.num_fogs,
        "vertices_final": plan.graph.num_vertices,
        "edges_final": plan.graph.num_edges,
        "parity_bit_identical": parity,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + parity guard (for scripts/ci.sh)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_updates.json"))
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--kind", default="gcn")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--cluster", default="1A+4B+1C")
    ap.add_argument("--network", default="wifi")
    ap.add_argument("--compressor", default="daq")
    ap.add_argument("--fracs", type=float, nargs="+",
                    default=[0.005, 0.01, 0.02, 0.05])
    ap.add_argument("--updates", type=int, nargs="+", default=[1, 4],
                    help="updates applied back-to-back per row")
    ap.add_argument("--aggregations", nargs="+",
                    default=["segment_sum", "pallas"])
    ap.add_argument("--localities", nargs="+",
                    default=["global", "local"],
                    help="'local' confines each delta to one partition "
                         "(exercises dirty-shard reuse)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        args.scale = 0.06
        args.fracs = [0.02]
        args.updates = [2]
        args.localities = ["global"]
        if args.out == ap.get_default("out"):   # don't dirty the worktree
            import tempfile
            args.out = os.path.join(tempfile.gettempdir(),
                                    "BENCH_updates.smoke.json")

    sweep = []
    print("aggregation,locality,delta_frac,n_updates,t_incremental_s,"
          "t_full_recompile_s,speedup,shards_rebuilt,parity")
    for aggregation in args.aggregations:
        for locality in args.localities:
            for frac in args.fracs:
                for n_updates in args.updates:
                    row = run_config(args, aggregation, frac, n_updates,
                                     args.seed, locality)
                    sweep.append(row)
                    print(f"{aggregation},{locality},{frac},{n_updates},"
                          f"{row['t_incremental_s']:.4f},"
                          f"{row['t_full_recompile_s']:.4f},"
                          f"{row['speedup']:.2f},{row['shards_rebuilt']},"
                          f"{row['parity_bit_identical']}")

    payload = {
        "benchmark": "dynamic_graph_updates",
        "config": {k: v for k, v in vars(args).items() if k != "smoke"},
        "sweep": sweep,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(sweep)} rows)")

    # Guards. Parity is unconditional: an incrementally repaired plan must
    # answer queries bit-identically to a full recompile of the same
    # mutated graph, AND its partition buffers (dirty-shard reuse
    # included) must equal a from-scratch rebuild.
    bad = [r for r in sweep if not r["parity_bit_identical"]]
    if bad:
        print(f"FAIL: {len(bad)} rows broke incremental==full parity")
        return 1
    print("PASS: incremental plans are bit-identical to full recompiles")
    if not args.smoke:
        # Acceptance: small deltas (<=5% of vertices) must beat a full
        # recompile in wall-clock on >=4-partition graphs.
        slow = [r for r in sweep
                if r["delta_frac"] <= 0.05 and r["speedup"] <= 1.0
                and all(m != "recompile" for m in r["modes"])]
        if slow:
            print(f"FAIL: {len(slow)} small-delta rows did not beat full "
                  f"recompile")
            return 1
        print("PASS: apply_delta beats full Engine.compile for small "
              "deltas")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
