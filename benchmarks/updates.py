"""Dynamic-graph update benchmark: incremental repair vs full recompile.

Streams random ``GraphDelta``s (vertex churn, edge churn, feature upserts)
into a compiled plan and times ``Engine.apply_delta`` — localized partition
repair + dirty-shard rebuild — against the full ``Engine.compile`` pipeline
on the same mutated graph, sweeping delta size x update count for two
pipeline shapes:

  * ``segment_sum``  — no pre-blocked shards; the repair win is skipped
    profiling/BGP/IEP.
  * ``pallas``       — block-CSR shards in the plan; the repair
    additionally reuses every clean shard's ELL-block-CSR operands.

Every row also checks *parity*: a query on the incrementally updated plan
must be bit-identical to a query on the freshly compiled plan (both via the
single-program executor, whose numerics are partition-independent).

A second sweep times the *incremental query* path: a
``Session(activation_cache=True)`` serving localized deltas on a grid
graph recomputes only the k-hop dirty frontier and scatter-merges into
cached activations — O(affected) instead of O(V) per query — against a
cache-less session on the same plan chain, asserting bit-parity every
round and recording the speedup under ``incremental_query``.

    PYTHONPATH=src python benchmarks/updates.py                  # full sweep
    PYTHONPATH=src python benchmarks/updates.py --smoke              # CI guard
    PYTHONPATH=src python benchmarks/updates.py --smoke-incremental  # CI guard
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(REPO, "src", "repro")):
    sys.path.insert(0, os.path.join(REPO, "src"))


def random_delta(graph, frac: float, rng: np.random.Generator,
                 assignment=None):
    """A mixed delta touching ~``frac`` of the graph's vertices.

    With ``assignment`` the delta is *localized*: every touched vertex
    lives in one randomly chosen partition — the geo-correlated churn of
    co-located IoT sensors, and the case where dirty-shard tracking keeps
    most block-CSR operands clean.
    """
    from repro.api import GraphDelta
    v = graph.num_vertices
    if assignment is None:
        pool = np.arange(v)
    else:
        p = int(rng.integers(int(assignment.max()) + 1))
        pool = np.flatnonzero(assignment == p)
        if pool.size < 4:
            pool = np.arange(v)
    k_add = max(1, int(frac * v))
    k_rem = min(max(1, int(frac * v * 0.5)), max(1, pool.size // 4))
    feats = rng.normal(size=(k_add, graph.feature_dim)).astype(np.float32)
    fanout = rng.integers(2, 5, size=k_add)
    senders = np.repeat(v + np.arange(k_add), fanout)
    targets = rng.choice(pool, size=int(fanout.sum()))
    removed = rng.choice(pool, size=k_rem, replace=False)
    in_pool = np.zeros(v, bool)
    in_pool[pool] = True
    cand = np.flatnonzero(in_pool[graph.receivers])
    eidx = rng.choice(cand, size=min(len(cand), max(
        1, int(frac * graph.num_edges * 0.1))), replace=False)
    rem_edges = np.stack([graph.senders[eidx], graph.receivers[eidx]],
                         axis=1)
    upd = np.setdiff1d(rng.choice(pool, size=min(k_add, pool.size),
                                  replace=False), removed)
    return GraphDelta(
        add_features=feats,
        add_edges=np.stack([senders, targets], axis=1),
        remove_vertices=removed, remove_edges=rem_edges,
        feature_ids=upd,
        feature_values=rng.normal(size=(len(upd), graph.feature_dim)))


def build_engine(args, aggregation: str):
    import jax

    from repro.api import Engine
    from repro.gnn import datasets, models

    graph = datasets.load(args.dataset, scale=args.scale, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), args.kind,
                             [graph.feature_dim, args.hidden, 8])
    # The pallas shape compiles block shards into the plan without needing
    # mesh devices (parity queries run through the single-program backend).
    executor = "mesh-bsp" if aggregation == "pallas" else "sim"
    engine = Engine((params, args.kind), cluster=args.cluster,
                    network=args.network, compressor=args.compressor,
                    executor=executor, aggregation=aggregation)
    return engine, graph


def parity_query(plan):
    """Partition-independent numerics: single-program segment_sum query."""
    sess = plan.session(executor="sim", aggregation="segment_sum")
    return sess.query().embeddings


def buffers_match(plan) -> bool:
    """The real parity guard: the incrementally rebuilt partition buffers
    (dirty-shard reuse included) must equal a from-scratch
    ``build_partitioned`` of the mutated graph at the repaired assignment,
    bit for bit.  Embedding parity alone cannot catch stale shard reuse —
    the single-program query ignores the partition layout entirely.
    """
    from repro.runtime import bsp
    pg = plan.partitioned
    ref = bsp.build_partitioned(plan.graph, plan.placement.assignment,
                                n=plan.num_fogs,
                                build_blocks=pg.local_csr is not None)
    for name in ("feats", "vertex_mask", "senders_global", "senders_halo",
                 "receivers_local", "edge_mask", "boundary_rows",
                 "boundary_mask", "part_of", "slot_of"):
        if not np.array_equal(getattr(ref, name), getattr(pg, name)):
            return False
    for attr in ("local_csr", "halo_csr"):
        a, b = getattr(ref, attr), getattr(pg, attr)
        if (a is None) != (b is None):
            return False
        if a is not None:
            for f in ("blocks", "cols", "mask"):
                if not np.array_equal(getattr(a, f), getattr(b, f)):
                    return False
            if (a.src_rows, a.out_rows) != (b.src_rows, b.out_rows):
                return False
    return True


def run_config(args, aggregation: str, frac: float, n_updates: int,
               seed: int, locality: str = "global") -> dict:
    engine, graph = build_engine(args, aggregation)
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    plan = engine.compile(graph)
    t_compile0 = time.perf_counter() - t0

    t_inc = t_full = 0.0
    modes = []
    shards_rebuilt = 0
    local_rebuilt = halo_rebuilt = 0
    for _ in range(n_updates):
        delta = random_delta(
            plan.graph, frac, rng,
            assignment=plan.placement.assignment
            if locality == "local" else None)
        t0 = time.perf_counter()
        plan_next = engine.apply_delta(plan, delta)
        t_inc += time.perf_counter() - t0
        t0 = time.perf_counter()
        plan_full = engine.compile(plan_next.graph)
        t_full += time.perf_counter() - t0
        modes.append(plan_next.update_report.mode)
        shards_rebuilt += plan_next.update_report.shards_rebuilt
        local_rebuilt += len(plan_next.update_report.dirty_local)
        halo_rebuilt += len(plan_next.update_report.dirty_halo)
        plan = plan_next

    emb_inc = parity_query(plan)
    emb_full = parity_query(plan_full)
    # Embeddings are partition-independent on the single-program path, so
    # the buffer comparison (after the whole chain) is the guard that can
    # actually trip on a repair bug.
    parity = bool(np.array_equal(emb_inc, emb_full)) and buffers_match(plan)
    return {
        "aggregation": aggregation, "locality": locality,
        "delta_frac": frac,
        "n_updates": n_updates, "t_compile_s": t_compile0,
        "t_incremental_s": t_inc, "t_full_recompile_s": t_full,
        "speedup": t_full / max(t_inc, 1e-12),
        "modes": modes, "shards_rebuilt": shards_rebuilt,
        "local_shards_rebuilt": local_rebuilt,
        "halo_shards_rebuilt": halo_rebuilt,
        "num_partitions": plan.num_fogs,
        "vertices_final": plan.graph.num_vertices,
        "edges_final": plan.graph.num_edges,
        "parity_bit_identical": parity,
    }


def grid_graph(side: int, feature_dim: int, seed: int):
    """4-neighbor grid of ``side**2`` sensors — the spatially local
    topology of co-located IoT deployments, where a delta's k-hop ball
    stays small (dense RMAT graphs blow past the frontier budget)."""
    from repro.gnn.graph import from_edge_list
    rng = np.random.default_rng(seed)
    v = side * side
    ids = np.arange(v).reshape(side, side)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()], axis=1)
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()], axis=1)
    feats = rng.normal(size=(v, feature_dim)).astype(np.float32)
    return from_edge_list(v, np.concatenate([right, down]), feats)


def incremental_delta(graph, frac: float, rng: np.random.Generator,
                      structural: bool):
    """A localized delta touching ~``frac`` of V contiguous (= spatially
    adjacent) vertices: feature upserts, plus an E-neutral edge swap
    (one pair added, one removed — removed-edge invalidation included)
    when ``structural``. Feature-only streams keep the Pallas
    incremental path armed (see core.frontier.ActivationCache), and a
    constant E keeps the full-recompute baseline at steady state
    instead of re-jitting on every new edge count.
    """
    from repro.api import GraphDelta
    v = graph.num_vertices
    k = max(1, int(frac * v))
    c = int(rng.integers(0, v - k))
    ids = np.arange(c, c + k)
    kw = dict(feature_ids=ids,
              feature_values=rng.normal(
                  size=(k, graph.feature_dim)).astype(np.float32))
    if structural:
        u, w = int(ids[0]), int(ids[-1])
        e = int(rng.integers(0, graph.num_edges))
        s, r = int(graph.senders[e]), int(graph.receivers[e])
        kw["add_edges"] = [(u, w), (w, u)]
        kw["remove_edges"] = [(s, r), (r, s)]
    return GraphDelta(**kw)


def run_incremental(args, aggregation: str, frac: float,
                    seed: int) -> dict:
    """Incremental (activation-cache) query vs full recompute on the
    same plan chain: two sessions fed identical deltas, one with
    ``activation_cache=True``; every round asserts bit-parity and times
    both executes."""
    import jax

    from repro.api import Engine
    from repro.gnn import models

    g = grid_graph(args.grid_side, 16, seed)
    params = models.gnn_init(jax.random.PRNGKey(seed), args.kind,
                             [g.feature_dim, args.hidden, 8])
    engine = Engine((params, args.kind), cluster=args.cluster,
                    network=args.network, compressor="none",
                    executor="sim", aggregation=aggregation)
    plan = engine.compile(g)
    inc = plan.session(activation_cache=True)
    ref = plan.session()
    rng = np.random.default_rng(seed)
    structural = aggregation != "pallas"
    # Warmup: populate the cache, compile the full + frontier programs.
    inc.execute(inc.collect(None))
    ref.execute(ref.collect(None))
    for _ in range(2):
        d0 = incremental_delta(inc.plan.graph, frac, rng, structural)
        inc.update(d0)
        ref.update(d0)
        inc.execute(inc.collect(None))
        ref.execute(ref.collect(None))
    times_inc, times_full = [], []
    parity = True
    hits = 0
    frontier_frac = []
    from repro.kernels import ops as _ops
    for _ in range(args.inc_rounds):
        d = incremental_delta(inc.plan.graph, frac, rng, structural)
        inc.update(d)
        ref.update(d)
        if aggregation == "pallas":
            # Plan-level operand build (cached per graph fingerprint):
            # both paths need it; don't bill it to whichever runs first.
            _ops.block_csr_for(inc.plan.graph)
        # np.asarray inside the timed region: a jax backend may hand
        # back an unmaterialized array, and the compute must be billed
        # to the path that launched it.
        t0 = time.perf_counter()
        e1 = np.asarray(inc.execute(inc.collect(None)))
        times_inc.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        e2 = np.asarray(ref.execute(ref.collect(None)))
        times_full.append(time.perf_counter() - t0)
        parity = parity and bool(np.array_equal(e1, e2))
        if inc.last_frontier is not None:
            hits += 1
            frontier_frac.append(inc.last_frontier.fraction)
    # Medians: a round that hits a not-yet-compiled frontier bucket pays
    # one-off jit tracing that steady-state serving never sees.
    t_inc = float(np.median(times_inc))
    t_full = float(np.median(times_full))
    return {
        "aggregation": aggregation, "delta_frac": frac,
        "rounds": args.inc_rounds, "incremental_hits": hits,
        "t_incremental_s": t_inc, "t_full_recompute_s": t_full,
        "speedup": t_full / max(t_inc, 1e-12),
        "frontier_fraction_mean": (float(np.mean(frontier_frac))
                                   if frontier_frac else None),
        "vertices": inc.plan.graph.num_vertices,
        "parity_bit_identical": parity,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + parity guard (for scripts/ci.sh)")
    ap.add_argument("--smoke-incremental", action="store_true",
                    help="tiny incremental-query parity guard only "
                         "(for scripts/ci.sh)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_updates.json"))
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--kind", default="gcn")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--cluster", default="1A+4B+1C")
    ap.add_argument("--network", default="wifi")
    ap.add_argument("--compressor", default="daq")
    ap.add_argument("--fracs", type=float, nargs="+",
                    default=[0.005, 0.01, 0.02, 0.05])
    ap.add_argument("--updates", type=int, nargs="+", default=[1, 4],
                    help="updates applied back-to-back per row")
    ap.add_argument("--aggregations", nargs="+",
                    default=["segment_sum", "pallas"])
    ap.add_argument("--localities", nargs="+",
                    default=["global", "local"],
                    help="'local' confines each delta to one partition "
                         "(exercises dirty-shard reuse)")
    ap.add_argument("--grid-side", type=int, default=200,
                    help="side of the grid graph for the incremental-"
                         "query sweep (V = side**2)")
    ap.add_argument("--inc-rounds", type=int, default=5,
                    help="timed delta->query rounds per incremental row")
    ap.add_argument("--inc-fracs", type=float, nargs="+",
                    default=[0.001, 0.005],
                    help="delta sizes for the incremental-query sweep")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        args.scale = 0.06
        args.fracs = [0.02]
        args.updates = [2]
        args.localities = ["global"]
    if args.smoke_incremental:
        if args.grid_side == ap.get_default("grid_side"):
            args.grid_side = 40
        if args.inc_rounds == ap.get_default("inc_rounds"):
            args.inc_rounds = 3
        if args.inc_fracs == ap.get_default("inc_fracs"):
            args.inc_fracs = [0.01]
    if ((args.smoke or args.smoke_incremental)
            and args.out == ap.get_default("out")):
        import tempfile                         # don't dirty the worktree
        args.out = os.path.join(tempfile.gettempdir(),
                                "BENCH_updates.smoke.json")

    sweep = []
    if not args.smoke_incremental:
        print("aggregation,locality,delta_frac,n_updates,t_incremental_s,"
              "t_full_recompile_s,speedup,shards_rebuilt,parity")
        for aggregation in args.aggregations:
            for locality in args.localities:
                for frac in args.fracs:
                    for n_updates in args.updates:
                        row = run_config(args, aggregation, frac, n_updates,
                                         args.seed, locality)
                        sweep.append(row)
                        print(f"{aggregation},{locality},{frac},{n_updates},"
                              f"{row['t_incremental_s']:.4f},"
                              f"{row['t_full_recompile_s']:.4f},"
                              f"{row['speedup']:.2f},"
                              f"{row['shards_rebuilt']},"
                              f"{row['parity_bit_identical']}")

    inc_sweep = []
    if args.smoke_incremental or not args.smoke:
        print("incremental-query: aggregation,delta_frac,hits,"
              "t_incremental_s,t_full_recompute_s,speedup,parity")
        for aggregation in args.aggregations:
            for frac in args.inc_fracs:
                row = run_incremental(args, aggregation, frac, args.seed)
                inc_sweep.append(row)
                print(f"{aggregation},{frac},"
                      f"{row['incremental_hits']}/{row['rounds']},"
                      f"{row['t_incremental_s']:.4f},"
                      f"{row['t_full_recompute_s']:.4f},"
                      f"{row['speedup']:.2f},"
                      f"{row['parity_bit_identical']}")

    payload = {
        "benchmark": "dynamic_graph_updates",
        "config": {k: v for k, v in vars(args).items()
                   if k not in ("smoke", "smoke_incremental")},
        "sweep": sweep,
        "incremental_query": inc_sweep,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(sweep) + len(inc_sweep)} rows)")

    # Guards. Parity is unconditional: an incrementally repaired plan must
    # answer queries bit-identically to a full recompile of the same
    # mutated graph, AND its partition buffers (dirty-shard reuse
    # included) must equal a from-scratch rebuild.
    bad = [r for r in sweep if not r["parity_bit_identical"]]
    if bad:
        print(f"FAIL: {len(bad)} rows broke incremental==full parity")
        return 1
    if sweep:
        print("PASS: incremental plans are bit-identical to full "
              "recompiles")
    # Incremental-query guards: bit-parity with full recompute always;
    # every round must actually take the frontier path.
    bad = [r for r in inc_sweep if not r["parity_bit_identical"]]
    if bad:
        print(f"FAIL: {len(bad)} incremental-query rows broke "
              f"cache==recompute parity")
        return 1
    cold = [r for r in inc_sweep if r["incremental_hits"] < r["rounds"]]
    if cold:
        print(f"FAIL: {len(cold)} incremental-query rows fell back to "
              f"full recompute")
        return 1
    if inc_sweep:
        print("PASS: cached incremental queries are bit-identical to "
              "full recompute")
    if not args.smoke and not args.smoke_incremental:
        # Acceptance: small deltas (<=5% of vertices) must beat a full
        # recompile in wall-clock on >=4-partition graphs.
        slow = [r for r in sweep
                if r["delta_frac"] <= 0.05 and r["speedup"] <= 1.0
                and all(m != "recompile" for m in r["modes"])]
        if slow:
            print(f"FAIL: {len(slow)} small-delta rows did not beat full "
                  f"recompile")
            return 1
        print("PASS: apply_delta beats full Engine.compile for small "
              "deltas")
        slow = [r for r in inc_sweep if r["speedup"] < 3.0]
        if slow:
            print(f"FAIL: {len(slow)} incremental-query rows under the "
                  f"3x speedup floor")
            return 1
        print("PASS: incremental queries beat full recompute >=3x on "
              "small deltas")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
