"""Geo-distributed fleet benchmark: site-count x skew x failure sweep.

Sweeps fleet size (number of fog sites) x zipfian site-popularity skew x
injected site failures for the geo-distributed serving layer
(``repro.api.fleet``) against two baselines on the *same* arrival trace:

  fleet           FleetServer: nearest-site routing from per-request geo
                  origins, load spillover, cloud failover, per-site
                  pipeline clocks, stale-tolerant halo exchange
                  (``halo_async`` + ``staleness_bound``).
  single-cluster  one Server over one fog-site plan — every request,
                  regardless of origin, funnels through one pipeline.
  all-cloud       one Server over the ``cloud`` executor plan — the
                  paper's Fig. 3 cloud baseline at fleet scale (WAN
                  upload + datacenter RTT per batch).

The arrival rate scales with fleet size (``load`` x sites x the
single-request sustainable rate), so the sweep measures whether the
fleet actually converts added sites into tail-latency headroom, and what
popularity skew and a mid-trace site failure cost. Failure runs inject
``set_down`` on the most popular site halfway through the trace; its
queued work must be rerouted, not dropped.

Writes the whole trajectory to ``BENCH_fleet.json``.

Acceptance guard (also run by scripts/ci.sh via --smoke): the fleet
beats all-cloud on p95 latency at >= 2 sites, and one injected site
failure drops zero requests (every submitted request is answered).

    PYTHONPATH=src python benchmarks/fleet.py            # full sweep
    PYTHONPATH=src python benchmarks/fleet.py --smoke    # CI guard
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(REPO, "src", "repro")):
    sys.path.insert(0, os.path.join(REPO, "src"))

#: centroid pool (lat, lon) — fleets of size N use the first N.
CITY_POOL = [
    ("stockholm", (59.33, 18.07)),
    ("vienna", (48.21, 16.37)),
    ("london", (51.51, -0.13)),
    ("lisbon", (38.72, -9.14)),
    ("athens", (37.98, 23.73)),
]


def build_fleet(args, nsites):
    import jax

    from repro.api import Engine
    from repro.gnn import datasets, models

    graph = datasets.load(args.dataset, scale=args.scale, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), args.kind,
                             [graph.feature_dim, args.hidden, 8])
    engine = Engine((params, args.kind), cluster=args.cluster,
                    network=args.network, compressor=args.compressor,
                    exchange="halo_async",
                    staleness_bound=args.staleness_bound)
    fleet = engine.compile_fleet(graph, dict(CITY_POOL[:nsites]))
    return fleet, graph


def run_fleet(fleet, trace, args, failures: int) -> dict:
    from repro.api.server import Response

    fs = fleet.server(capacity=args.capacity, max_batch=args.max_batch)
    t0 = time.perf_counter()
    if failures:
        half = len(trace) // 2
        for r in trace[:half]:
            fs.submit(r)
        rerouted = 0
        for name in fleet.site_names[:failures]:
            rerouted += fs.set_down(name)
        for r in trace[half:]:
            fs.submit(r)
        out = fs.drain()
    else:
        rerouted = 0
        out = fs.replay(list(trace))
    wall = time.perf_counter() - t0
    summary = fs.summarize(out)
    summary["wall_s"] = wall
    summary["rerouted"] = rerouted
    summary["answered"] = sum(1 for r in out if isinstance(r, Response))
    return summary


def run_baseline(plan, trace, args) -> dict:
    from repro.api import Server
    server = plan.server(max_batch=args.max_batch)
    t0 = time.perf_counter()
    out = server.replay(list(trace))
    wall = time.perf_counter() - t0
    summary = Server.summarize(out)
    summary["wall_s"] = wall
    return summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + pass/fail guard (for scripts/ci.sh)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_fleet.json"))
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--kind", default="gcn")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--cluster", default="1A+2B")
    ap.add_argument("--network", default="wifi")
    ap.add_argument("--compressor", default="daq")
    ap.add_argument("--staleness-bound", type=int, default=2)
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--sites", type=int, nargs="+", default=[1, 2, 3, 4])
    ap.add_argument("--zipf", type=float, nargs="+", default=[0.0, 1.5],
                    help="site-popularity skew exponents (0 = uniform)")
    ap.add_argument("--failures", type=int, nargs="+", default=[0, 1],
                    help="how many sites to take down mid-trace")
    ap.add_argument("--load", type=float, default=1.0,
                    help="arrival rate as a multiple of sites x the "
                         "single-request sustainable rate")
    ap.add_argument("--capacity", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--spread", type=float, default=1.5,
                    help="gaussian origin scatter around centroids, degrees")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        args.scale = 0.05
        args.requests = 48
        args.sites = [2]
        args.zipf = [1.0]
        args.failures = [0, 1]
        if args.out == ap.get_default("out"):   # don't dirty the worktree
            import tempfile
            args.out = os.path.join(tempfile.gettempdir(),
                                    "BENCH_fleet.smoke.json")
    if max(args.sites) > len(CITY_POOL):
        raise SystemExit(f"--sites max is {len(CITY_POOL)} "
                         f"(the centroid pool)")

    from repro.api import traces

    sweep = []
    print("serving,sites,zipf,failures,p95_s,throughput_rps,"
          "local,spilled,failed_over,rerouted,dropped")
    graph = None
    for nsites in sorted(set(args.sites)):
        fleet, graph = build_fleet(args, nsites)
        s1 = fleet.sites[0].plan.session().account().total_latency
        rate = args.load * nsites / s1
        for zipf in args.zipf:
            origin_fn = traces.geo_origins(
                fleet.centroids(), spread=args.spread, zipf_s=zipf,
                seed=args.seed)
            trace = traces.poisson(args.requests, rate, seed=args.seed,
                                   origin_fn=origin_fn)
            baselines = {
                "single-cluster": run_baseline(fleet.sites[0].plan,
                                               trace, args),
                "all-cloud": run_baseline(fleet.cloud_plan, trace, args),
            }
            for name, row in baselines.items():
                row.update(serving=name, sites=nsites, zipf=zipf,
                           failures=0, rate_rps=rate)
                sweep.append(row)
                print(f"{name},{nsites},{zipf},0,"
                      f"{row['latency_p95_s']:.3f},"
                      f"{row['throughput_rps']:.2f},-,-,-,0,0")
            for failures in args.failures:
                if failures >= nsites and failures > 0:
                    continue   # keep at least one site up
                row = run_fleet(fleet, trace, args, failures)
                row.update(serving="fleet", sites=nsites, zipf=zipf,
                           failures=failures, rate_rps=rate)
                sweep.append(row)
                rt = row["routes"]
                print(f"fleet,{nsites},{zipf},{failures},"
                      f"{row['latency_p95_s']:.3f},"
                      f"{row['throughput_rps']:.2f},{rt['local']},"
                      f"{rt['spilled']},{rt['failed_over']},"
                      f"{row['rerouted']},{row['dropped']}")

    payload = {
        "benchmark": "fleet_geo_serving",
        "config": {k: v for k, v in vars(args).items() if k != "smoke"},
        "graph": {"vertices": graph.num_vertices,
                  "edges": graph.num_edges},
        "centroids": dict(CITY_POOL),
        "rows": sweep,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(sweep)} rows)")

    # Acceptance guard: (1) at >= 2 sites the fleet beats the all-cloud
    # baseline on p95 on every (zipf, no-failure) point; (2) an injected
    # site failure drops nothing — every submitted request is answered.
    failures_list = []
    cloud = {(r["sites"], r["zipf"]): r for r in sweep
             if r["serving"] == "all-cloud"}
    for r in sweep:
        if r["serving"] != "fleet":
            continue
        if r["failures"] == 0 and r["sites"] >= 2:
            c = cloud[(r["sites"], r["zipf"])]
            if not r["latency_p95_s"] < c["latency_p95_s"]:
                failures_list.append(
                    f"sites={r['sites']} zipf={r['zipf']}: fleet p95 "
                    f"{r['latency_p95_s']:.3f}s !< all-cloud "
                    f"{c['latency_p95_s']:.3f}s")
        if r["dropped"] != 0 or r["answered"] != args.requests:
            failures_list.append(
                f"sites={r['sites']} zipf={r['zipf']} "
                f"failures={r['failures']}: answered {r['answered']}"
                f"/{args.requests}, dropped={r['dropped']}")
    if failures_list:
        print("FLEET GUARD FAILED:")
        for f in failures_list:
            print(f"  - {f}")
        return 1
    print("fleet guard OK: fleet < all-cloud p95 at >= 2 sites; "
          "zero drops under site failure")
    return 0


if __name__ == "__main__":
    sys.exit(main())
