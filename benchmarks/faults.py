"""Node-level fault-tolerance benchmark: availability vs chaos intensity.

Replays one Poisson arrival trace against the same compiled plan under a
sweep of seeded chaos schedules (``repro.api.faults``) of increasing
crash rate, plus transient halo-exchange losses and stragglers, and
measures what the recovery tiers cost:

  availability   answered / admitted — stays 1.0 by construction (a
                 crash fails the shard over and replays in-flight work;
                 nothing is dropped)
  p95 latency    grows with crash rate: each failover charges the shard
                 re-upload + rebuild time to the batch that absorbs it,
                 and the surviving cluster serves at degraded capacity
  retried /      how many responses paid a tier-1 backoff retry or were
  recovered      served through any recovery tier at all

A fault-free run with an *empty* schedule installed is compared against
a run with no injector at all — the chaos machinery must be free when
nothing fails.

Writes the whole trajectory to ``BENCH_faults.json``.

Acceptance guard (also run by scripts/ci.sh via --smoke): zero drops at
every crash rate (every submitted request is answered), the fault-free
p95 with an installed-but-empty schedule is within 5% of the no-injector
baseline, availability >= 0.99 at the default crash rate, and a
failover plan is bit-identical to a fresh ``Engine.compile`` on the
surviving cluster.

    PYTHONPATH=src python benchmarks/faults.py            # full sweep
    PYTHONPATH=src python benchmarks/faults.py --smoke    # CI guard
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(REPO, "src", "repro")):
    sys.path.insert(0, os.path.join(REPO, "src"))


def build_plan(args):
    import jax

    from repro.api import Engine
    from repro.gnn import datasets, models

    graph = datasets.load(args.dataset, scale=args.scale, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), args.kind,
                             [graph.feature_dim, args.hidden, 8])
    engine = Engine((params, args.kind), cluster=args.cluster,
                    network=args.network, executor=args.executor,
                    exchange="halo_async",
                    staleness_bound=args.staleness_bound)
    return engine, engine.compile(graph), graph


def run_trace(plan, trace, args, schedule) -> dict:
    from repro.api import Server

    server = plan.server(max_batch=args.max_batch, faults=schedule)
    t0 = time.perf_counter()
    out = server.replay(list(trace))
    wall = time.perf_counter() - t0
    summary = Server.summarize(out)
    summary["wall_s"] = wall
    summary["answered"] = summary["requests"]
    summary["replayed"] = server.replayed
    summary["crashed_now"] = sorted(server._crashed)
    return summary


def check_failover_parity(engine, plan) -> str:
    """One crash, two derivations: ``fail_nodes(mode="recompile")`` must
    equal a fresh ``Engine.compile`` on the surviving cluster — same
    layout, bit-identical embeddings. Returns "" or a failure message."""
    import dataclasses

    import numpy as np

    from repro.api import Engine

    crashed = plan.cluster.nodes[-1].name
    failover = engine.fail_nodes(plan, [crashed], mode="recompile")
    survivors = dataclasses.replace(
        plan.cluster, nodes=[n for n in plan.cluster.nodes
                             if n.name != crashed])
    cfg = plan.config
    fresh = Engine(plan.model, survivors, partitioner=cfg.partitioner,
                   placement=cfg.placement, compressor=cfg.compressor,
                   exchange=cfg.exchange, executor=cfg.executor,
                   network=cfg.network, seed=cfg.seed,
                   sync_cost=cfg.sync_cost, aggregation=cfg.aggregation,
                   staleness_bound=cfg.staleness_bound
                   ).compile(plan.graph)
    if not np.array_equal(failover.placement.assignment,
                          fresh.placement.assignment):
        return "failover assignment differs from fresh survivor compile"
    a = failover.session().query().embeddings
    b = fresh.session().query().embeddings
    if not np.array_equal(a, b):
        return ("failover embeddings differ from fresh survivor compile "
                f"(max |d| {float(np.abs(a - b).max()):.3e})")
    return ""


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + pass/fail guard (for scripts/ci.sh)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_faults.json"))
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--kind", default="gcn")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--cluster", default="1A+3B")
    ap.add_argument("--network", default="wifi")
    ap.add_argument("--executor", default="sim")
    ap.add_argument("--staleness-bound", type=int, default=2)
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--crash-rates", type=float, nargs="+",
                    default=[0.0, 0.2, 0.5, 1.0],
                    help="crash events per simulated second (each paired "
                         "with a recover)")
    ap.add_argument("--default-crash-rate", type=float, default=0.5,
                    help="the rate the availability guard is asserted at")
    ap.add_argument("--loss-rate", type=float, default=1.0,
                    help="transient halo-loss events per simulated second")
    ap.add_argument("--straggler-rate", type=float, default=0.5)
    ap.add_argument("--load", type=float, default=1.0,
                    help="arrival rate as a multiple of the sustainable "
                         "single-request rate")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        args.scale = 0.05
        args.requests = 48
        args.crash_rates = [0.0, 0.5]
        args.default_crash_rate = 0.5
        if args.out == ap.get_default("out"):   # don't dirty the worktree
            import tempfile
            args.out = os.path.join(tempfile.gettempdir(),
                                    "BENCH_faults.smoke.json")
    if args.default_crash_rate not in args.crash_rates:
        args.crash_rates = sorted(set(args.crash_rates)
                                  | {args.default_crash_rate})

    from repro.api import traces
    from repro.api.faults import FaultSchedule

    engine, plan, graph = build_plan(args)
    nodes = [n.name for n in plan.cluster.nodes]
    rate = args.load / plan.session().account().total_latency
    horizon = args.requests / rate
    trace = traces.poisson(args.requests, rate, seed=args.seed)

    sweep = []
    print("schedule,crash_rate,events,p95_s,availability,answered,"
          "retried,recovered,replayed")

    # No injector at all: the reference the empty-schedule run must match.
    base = run_trace(plan, trace, args, None)
    base.update(schedule="none", crash_rate=0.0, events=0)
    sweep.append(base)
    print(f"none,0.0,0,{base['latency_p95_s']:.4f},"
          f"{base['availability']:.3f},{base['answered']},0,0,0")

    for crash_rate in sorted(set(args.crash_rates)):
        sched = FaultSchedule.random(
            nodes, horizon=horizon, crash_rate=crash_rate,
            loss_rate=args.loss_rate if crash_rate else 0.0,
            straggler_rate=args.straggler_rate if crash_rate else 0.0,
            seed=args.seed)
        row = run_trace(plan, trace, args, sched)
        row.update(schedule="chaos" if len(sched) else "empty",
                   crash_rate=crash_rate, events=len(sched),
                   event_counts=sched.counts())
        sweep.append(row)
        print(f"{row['schedule']},{crash_rate},{row['events']},"
              f"{row['latency_p95_s']:.4f},{row['availability']:.3f},"
              f"{row['answered']},{row['retried']},{row['recovered']},"
              f"{row['replayed']}")

    payload = {
        "benchmark": "fault_tolerance",
        "config": {k: v for k, v in vars(args).items() if k != "smoke"},
        "graph": {"vertices": graph.num_vertices, "edges": graph.num_edges},
        "nodes": nodes,
        "rate_rps": rate,
        "horizon_s": horizon,
        "rows": sweep,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(sweep)} rows)")

    # Acceptance guard: (1) zero drops at every crash rate; (2) the empty
    # schedule costs nothing (p95 within 5% of no-injector); (3)
    # availability >= 0.99 at the default crash rate; (4) failover plans
    # are bit-identical to fresh survivor compiles.
    failures = []
    for row in sweep:
        if row["answered"] != args.requests:
            failures.append(
                f"crash_rate={row['crash_rate']} ({row['schedule']}): "
                f"answered {row['answered']}/{args.requests} — dropped "
                "requests")
    empty = next(r for r in sweep
                 if r["schedule"] == "empty" and r["crash_rate"] == 0.0)
    if empty["latency_p95_s"] > base["latency_p95_s"] * 1.05 + 1e-12:
        failures.append(
            f"fault-free overhead: empty-schedule p95 "
            f"{empty['latency_p95_s']:.4f}s vs no-injector "
            f"{base['latency_p95_s']:.4f}s (> 5%)")
    at_default = next(r for r in sweep
                      if r["crash_rate"] == args.default_crash_rate
                      and r["schedule"] != "none")
    if at_default["availability"] < 0.99:
        failures.append(
            f"availability {at_default['availability']:.3f} < 0.99 at "
            f"crash_rate={args.default_crash_rate}")
    parity = check_failover_parity(engine, plan)
    if parity:
        failures.append(parity)
    if failures:
        print("FAULTS GUARD FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("faults guard OK: zero drops at every crash rate; fault-free "
          "overhead <= 5%; availability >= 0.99; failover == fresh "
          "survivor compile")
    return 0


if __name__ == "__main__":
    sys.exit(main())
